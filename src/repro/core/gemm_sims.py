"""Functional + cycle-accurate simulators for the four GEMM units.

Each simulator consumes *already-quantized* integer matrices ``a: (M, K)`` and
``b: (K, N)`` (int8 container holding w-bit values) and produces the unit's
output in int32 (exact designs) or float32 (stochastic uGEMM), together with
the latency the unit would incur.

Two fidelity levels:

* ``*_exact`` — fast vectorized equivalents used by the model-level inference
  path.  For tuGEMM/tubGEMM/bGEMM the hardware is deterministic, so the exact
  functional result *is* integer GEMM; the value of the unary designs lies in
  the PPA/latency model (see ``core.ppa``), not a different numeric answer.
* ``*_stream`` — cycle-faithful stream/counter simulators.  These exist to
  *prove* the functional equivalence claim (tests assert bit-identity with
  the oracle) and to model uGEMM's stochastic error.

The stream engine is **slot-parallel**: instead of scanning one time slot per
step (the original triple-nested ``lax.scan``, O(K·L²) sequential steps for
tuGEMM), it materializes the unary pulse trains with ``core.unary`` encoders
and contracts the slot axes in a single einsum.  Every slot of the hardware
schedule is still explicitly represented — the sum over slot axes *is* the
counter network — so results (outputs **and** cycle counts) are bit-identical
to the sequential scans, which are kept as ``*_stream_scan`` references and
cross-checked in the tests.

Latency formulas (paper §II, outer-product dataflow, ``N`` = common dim = K):

    bGEMM    : K
    uGEMM    : 2^w
    tuGEMM   : K * (2^(w-1))^2
    tubGEMM  : K * 2^(w-2)

Dynamic (sparsity-aware, Eq. 1) latency for the temporal designs scales the
worst case by the occupied fraction of the unary stream, which in hardware is
set by the *largest magnitude in the tile* (all lanes wait for the slowest
counter): ``dyn = wc * max|q| / Vmax-equivalent``.

Designs are dispatched through a registry (:func:`register_design`); the
built-in four register at import.  New PE-array designs plug in without
touching the dispatch functions.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.quantization import vmax
from repro.core import unary

__all__ = [
    "DESIGNS",
    "DesignSpec",
    "register_design",
    "get_design",
    "registry_snapshot",
    "registry_restore",
    "scoped_registry",
    "wc_cycles",
    "dynamic_cycles_from_sparsity",
    "dynamic_cycles_from_operand",
    "bgemm_exact",
    "tugemm_exact",
    "tubgemm_exact",
    "ugemm_exact",
    "tugemm_stream",
    "tubgemm_stream",
    "ugemm_stream",
    "tugemm_stream_scan",
    "tubgemm_stream_scan",
    "ugemm_stream_scan",
    "gemm",
    "gemm_batched",
    "stream_gemm",
    "rel_rmse",
]


def rel_rmse(est, oracle) -> float:
    """Relative RMSE of an estimate vs its oracle (0.0 means bit-exact).

    The accuracy metric every uGEMM-vs-binary comparison in this repo uses;
    guarded against an all-zero oracle.
    """
    est = np.asarray(est, np.float64)
    oracle = np.asarray(oracle, np.float64)
    denom = float(np.sqrt(np.mean(oracle ** 2)))
    return float(np.sqrt(np.mean((est - oracle) ** 2)) / max(denom, 1e-12))


# ---------------------------------------------------------------------------
# Design registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DesignSpec:
    """Everything the dispatch layer needs to know about one PE-array design.

    ``exact_fn(a, b, bits)`` — fast functional GEMM.
    ``stream_fn(a, b, bits)`` — cycle-faithful sim, returns ``(out, cycles)``.
    ``wc_cycles_fn(bits, common_dim)`` — worst-case latency formula.
    ``sparsity_aware`` — True iff the unit early-terminates on bit sparsity
    (paper Eq. 1 applies); False runs at worst case regardless of operands.
    ``dyn_operand_fn(bits, step_max)`` — dynamic cycles from the per-outer-
    product-step max magnitudes ``step_max: (K,)``; None means worst case.
    ``exact`` — True iff the functional result is deterministic integer GEMM
    (bit-identical to the binary oracle); False for stochastic designs.
    """

    name: str
    exact_fn: Callable[[jax.Array, jax.Array, int], jax.Array]
    stream_fn: Callable[[jax.Array, jax.Array, int], tuple]
    wc_cycles_fn: Callable[[int, int], int]
    sparsity_aware: bool = False
    dyn_operand_fn: Callable[[int, jax.Array], jax.Array] | None = None
    exact: bool = True


_REGISTRY: dict[str, DesignSpec] = {}

# Canonical design order (rebuilt by register_design; kept a plain tuple for
# the many call sites that iterate/parametrize over it).
DESIGNS: tuple[str, ...] = ()


def register_design(name: str,
                    exact_fn: Callable,
                    stream_fn: Callable,
                    wc_cycles_fn: Callable[[int, int], int],
                    *,
                    sparsity_aware: bool = False,
                    dyn_operand_fn: Callable | None = None,
                    exact: bool = True,
                    overwrite: bool = False) -> DesignSpec:
    """Register a GEMM unit design with the dispatch layer.

    Replaces the old if-chains in ``gemm`` / ``wc_cycles`` /
    ``dynamic_cycles_from_sparsity``: everything dispatching through this
    module (``gemm``, ``gemm_batched``, ``stream_gemm``, the cycle models)
    picks new designs up immediately.  PPA *pricing* additionally needs
    paper-calibrated synthesis data, which ``core.ppa`` only has for the
    built-in four — pricing an uncalibrated design raises a clear error.
    Consumers holding a from-import snapshot of ``DESIGNS`` (taken at their
    import time) won't see later registrations; read ``gemm_sims.DESIGNS``
    via the module attribute for a live view.
    """
    global DESIGNS
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"design {name!r} already registered")
    spec = DesignSpec(name=name, exact_fn=exact_fn, stream_fn=stream_fn,
                      wc_cycles_fn=wc_cycles_fn,
                      sparsity_aware=sparsity_aware,
                      dyn_operand_fn=dyn_operand_fn,
                      exact=exact)
    _REGISTRY[name] = spec
    DESIGNS = tuple(_REGISTRY)
    return spec


def get_design(name: str) -> DesignSpec:
    """Look up a registered :class:`DesignSpec` by name (ValueError if absent)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown design {name!r}") from None


def registry_snapshot() -> dict[str, DesignSpec]:
    """Copy of the current design registry, for :func:`registry_restore`.

    The only supported way to save/restore registry state: restoring through
    this API keeps ``DESIGNS`` in sync with ``_REGISTRY`` through the same
    code path :func:`register_design` uses, so consumers reading the module
    attribute never observe a desynced view.  (Consumers holding a
    ``from gemm_sims import DESIGNS`` snapshot are pinned to their import-time
    tuple either way — read ``gemm_sims.DESIGNS`` for a live view.)
    """
    return dict(_REGISTRY)


def registry_restore(snapshot: dict[str, DesignSpec]) -> None:
    """Reset the registry (and ``DESIGNS``) to a :func:`registry_snapshot`."""
    global DESIGNS
    _REGISTRY.clear()
    _REGISTRY.update(snapshot)
    DESIGNS = tuple(_REGISTRY)


@contextlib.contextmanager
def scoped_registry():
    """Context manager: registry mutations inside the block don't escape it.

    Snapshots on entry and restores on exit (exception-safe, nestable).
    Yields the snapshot taken at entry.
    """
    snapshot = registry_snapshot()
    try:
        yield snapshot
    finally:
        registry_restore(snapshot)


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------

def wc_cycles(design: str, bits: int, common_dim: int) -> int:
    """Worst-case cycles for one (n x n x common_dim) GEMM on the unit.

    Args: ``design`` — registered design name; ``bits`` — operand bit-width
    w; ``common_dim`` — contraction length K the unit streams over.
    Returns: clock cycles (dimensionless count — multiply by
    ``ppa.CLOCK_PERIOD_NS`` for ns).  §II formulas: bGEMM K, uGEMM 2^w,
    tuGEMM K*(2^(w-1))^2, tubGEMM K*2^(w-2).
    """
    return get_design(design).wc_cycles_fn(bits, common_dim)


def dynamic_cycles_from_sparsity(design: str, bits: int, common_dim: int,
                                 bit_sparsity: float) -> float:
    """Paper Eq. 1: dynamic latency = WC latency * (1 - bit_sparsity).

    Only the temporal designs (tuGEMM, tubGEMM) exploit bit sparsity; uGEMM and
    bGEMM run at worst case regardless of operand values.

    Args: as :func:`wc_cycles` plus ``bit_sparsity`` — fraction of zero slots
    in the temporal operand's unary stream, in [0, 1).
    Returns: expected cycles (float; fractional because sparsity is a mean).
    """
    wc = wc_cycles(design, bits, common_dim)
    if get_design(design).sparsity_aware:
        return wc * (1.0 - float(bit_sparsity))
    return float(wc)


def dynamic_cycles_from_operand(design: str, bits: int, q_weights) -> float:
    """Dynamic cycles for a concrete quantized operand tile.

    Early termination is gated by the largest magnitude in the tile — the
    paper's "largest value bottlenecks GEMM compute".  ``q_weights`` is the
    temporal-encoded operand, shape (K, n) or (K,) per outer-product step; we
    use the per-step max magnitude summed over steps.
    """
    q = jnp.asarray(q_weights, jnp.int32)
    if q.ndim == 1:
        q = q[:, None]
    k = q.shape[0]
    spec = get_design(design)
    if spec.dyn_operand_fn is None:
        return float(spec.wc_cycles_fn(bits, k))
    step_max = jnp.max(jnp.abs(q), axis=tuple(range(1, q.ndim)))  # (K,)
    return float(spec.dyn_operand_fn(bits, step_max))


def _tugemm_dyn(bits: int, step_max: jax.Array) -> jax.Array:
    # outer stream gates inner full pass
    return jnp.sum((2 ** (bits - 1)) * step_max)


def _tubgemm_dyn(bits: int, step_max: jax.Array) -> jax.Array:
    # 2-unary stream slots actually used
    per_step = jnp.ceil(step_max / 2.0)
    return jnp.sum(jnp.maximum(per_step, 1))


# ---------------------------------------------------------------------------
# Fast functional paths
# ---------------------------------------------------------------------------

@jax.jit
def bgemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """Conventional binary GEMM: the int32 oracle every exact design equals.

    Args: ``a`` (M, K) and ``b`` (K, N) integer matrices (any int dtype
    holding the quantized codes).  Returns: (M, N) int32 product.
    """
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def tugemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """tuGEMM is deterministic: functional result == integer GEMM.

    Args/returns: as :func:`bgemm_exact`.  The design's value is its PPA
    profile (``core.ppa``), not a different numeric answer.
    """
    return bgemm_exact(a, b)


def tubgemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """tubGEMM is deterministic: functional result == integer GEMM.

    Args/returns: as :func:`bgemm_exact`.
    """
    return bgemm_exact(a, b)


def _unified_streams(bits: int):
    """Comparator sequences of uGEMM's *unified* multiplier.

    Port A streams **temporal** (plain up-counter comparator: slot t fires iff
    ``t/L < |a|/V``); port B streams **rate** (bit-reversed / van-der-Corput
    comparator).  Counting A AND B over the 2^w slots approximates
    ``|a|*|b|*L/V^2`` with low-discrepancy error — this temporal x rate pairing
    is what makes the unified units far more accurate than rate x rate
    (measured GEMM rel-RMSE ~1.8% at 8-bit, exact at 2-bit; rate x rate is
    ~15%).  Sign-magnitude handles bipolar values; pure bipolar XNOR streams
    were evaluated and rejected (high SC variance at small magnitudes).
    """
    L = unary.rate_stream_len(bits)
    temporal = jnp.arange(L, dtype=jnp.float32) / L
    rate = unary.van_der_corput(L)
    return temporal, rate, L


@partial(jax.jit, static_argnames=("bits",))
def ugemm_exact(a: jax.Array, b: jax.Array, bits: int = 8) -> jax.Array:
    """Closed-form evaluation of the unified stream simulator.

    Fast path for model-level "run inference on a uGEMM array" studies:
    evaluates the deterministic AND-count per scalar product from a
    (V+1)x(V+1) lookup table instead of materializing (L, M, K, N) streams.
    Bit-identical to ``ugemm_stream`` — the count only depends on the two
    magnitudes and the fixed comparator sequences.
    """
    temporal, rate, L = _unified_streams(bits)
    V = vmax(bits)
    mags = jnp.arange(V + 1, dtype=jnp.int32)
    sa = (temporal[None, :] < (mags[:, None] / V)).astype(jnp.float32)  # (V+1, L)
    sb = (rate[None, :] < (mags[:, None] / V)).astype(jnp.float32)      # (V+1, L)
    counts = jnp.einsum("al,bl->ab", sa, sb)                            # (V+1, V+1)
    prod_lut = counts * (V * V / L)                                      # est of |a||b|
    ia = jnp.abs(a.astype(jnp.int32))
    ib = jnp.abs(b.astype(jnp.int32))
    est = prod_lut[ia[:, :, None], ib[None, :, :]]                       # (M, K, N)
    sgn = (jnp.sign(a.astype(jnp.int32))[:, :, None]
           * jnp.sign(b.astype(jnp.int32))[None, :, :]).astype(jnp.float32)
    return jnp.sum(est * sgn, axis=1)  # adder-tree accumulation over K is exact


# ---------------------------------------------------------------------------
# Cycle-accurate stream simulators — slot-parallel vectorized engine
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("bits",))
def tugemm_stream(a: jax.Array, b: jax.Array, bits: int):
    """Counter-based fully-temporal GEMM, slot-parallel form.

    Hardware view: for each outer-product step k, stream a's temporal bits;
    for every 1-slot of a, replay b's full temporal stream into per-output
    counters.  The einsum below contracts both slot axes and K at once: slot
    pair (i, j) of step k contributes ``pulse_a[i] * pulse_b[j] * sign`` —
    exactly the counter increments the sequential scan applies one at a time.
    cycles(WC) = K * L^2 with L = 2^(w-1) slot budget.  Returns (out, cycles).
    """
    L = unary.temporal_stream_len(bits)
    stream_a, sign_a = unary.encode_temporal(a, bits)   # (L, M, K), (M, K)
    stream_b, sign_b = unary.encode_temporal(b, bits)   # (L, K, N), (K, N)
    pa = stream_a * sign_a[None]
    pb = stream_b * sign_b[None]
    out = jnp.einsum("imk,jkn->mn", pa, pb,
                     preferred_element_type=jnp.int32).astype(jnp.int32)
    return out, a.shape[1] * L * L


@partial(jax.jit, static_argnames=("bits",))
def tubgemm_stream(a: jax.Array, b: jax.Array, bits: int):
    """Temporal-unary (a, 2-unary) x binary (b) hybrid GEMM, slot-parallel.

    Hardware view: per outer-product step k, a's magnitude streams in 2-unary
    (L2 = 2^(w-2) slots, each slot worth 2), with the odd bit folded into slot
    0; b stays binary and is conditionally added into accumulators every slot.
    The (slot, M, K) weight train below is that schedule verbatim; the einsum
    sums slot contributions the way the accumulator bank does.
    cycles(WC) = K * L2.  Returns (out, cycles).
    """
    L2 = unary.tub_stream_len(bits)
    stream2, lsb, sign = unary.encode_tub(a, bits)      # (L2, M, K), (M, K), (M, K)
    weights = 2 * stream2
    weights = weights.at[0].add(lsb)                    # odd bit rides slot 0
    weights = weights * sign[None]
    out = jnp.einsum("tmk,kn->mn", weights, b.astype(jnp.int32),
                     preferred_element_type=jnp.int32).astype(jnp.int32)
    return out, a.shape[1] * L2


@partial(jax.jit, static_argnames=("bits",))
def ugemm_stream(a: jax.Array, b: jax.Array, bits: int):
    """Unified-unary stochastic GEMM (uGEMM-style) simulator, slot-parallel.

    Port A streams temporal, port B streams rate (see ``_unified_streams``);
    slot-wise AND multipliers feed signed parallel adder trees (binary
    counters — accumulation over K is exact, only the multiply is stochastic).
    The signed pulse trains are kept in float32 so the (t, k) contraction
    takes the BLAS path (int32 matmul has no fast CPU kernel): every summand
    is in {-1, 0, 1} and every partial count is an exact integer < 2^24, so
    fp32 accumulation is exact in any order — bit-identical to both the int
    counters and the fp32 scan reference (valid while L * K < 2^24).
    Returns (float estimate, cycles = 2^w).
    """
    temporal, rate, L = _unified_streams(bits)
    V = vmax(bits)
    pa = jnp.abs(a.astype(jnp.int32)).astype(jnp.float32) / V
    pb = jnp.abs(b.astype(jnp.int32)).astype(jnp.float32) / V
    at = ((temporal[:, None, None] < pa[None]).astype(jnp.float32)
          * jnp.sign(a.astype(jnp.float32))[None])      # (L, M, K)
    bt = ((rate[:, None, None] < pb[None]).astype(jnp.float32)
          * jnp.sign(b.astype(jnp.float32))[None])      # (L, K, N)
    counts = jnp.einsum("tmk,tkn->mn", at, bt)
    return counts * (V * V / L), L


# ---------------------------------------------------------------------------
# Sequential scan references (the seed implementations, kept as the
# semantic ground truth the vectorized engine is tested against)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("bits",))
def tugemm_stream_scan(a: jax.Array, b: jax.Array, bits: int):
    """One-slot-per-step scan reference for :func:`tugemm_stream`."""
    L = 2 ** (bits - 1)  # slot budget the paper's latency formula uses
    ia = jnp.abs(a.astype(jnp.int32))
    ib = jnp.abs(b.astype(jnp.int32))
    sa = jnp.sign(a.astype(jnp.int32))
    sb = jnp.sign(b.astype(jnp.int32))
    K = a.shape[1]

    def outer_step(acc, k):
        ak, sak = ia[:, k], sa[:, k]          # (M,)
        bk, sbk = ib[k, :], sb[k, :]          # (N,)

        def a_slot(acc, i):
            gate = (i < ak).astype(jnp.int32)  # (M,)

            def b_slot(acc, j):
                pulse = (j < bk).astype(jnp.int32)  # (N,)
                contrib = (gate[:, None] * pulse[None, :]
                           * (sak[:, None] * sbk[None, :]))
                return acc + contrib, None

            acc, _ = lax.scan(b_slot, acc, jnp.arange(L))
            return acc, None

        acc, _ = lax.scan(a_slot, acc, jnp.arange(L))
        return acc, None

    out0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    out, _ = lax.scan(outer_step, out0, jnp.arange(K))
    return out, K * L * L


@partial(jax.jit, static_argnames=("bits",))
def tubgemm_stream_scan(a: jax.Array, b: jax.Array, bits: int):
    """One-slot-per-step scan reference for :func:`tubgemm_stream`."""
    L2 = max(1, 2 ** (bits - 2))
    ia = jnp.abs(a.astype(jnp.int32))
    sa = jnp.sign(a.astype(jnp.int32))
    ib = b.astype(jnp.int32)
    K = a.shape[1]

    def outer_step(acc, k):
        ak, sak = ia[:, k], sa[:, k]   # (M,)
        bk = ib[k, :]                   # (N,)
        v1, v0 = ak // 2, ak % 2

        def slot(acc, t):
            two_gate = 2 * (t < v1).astype(jnp.int32)        # weight-2 slots
            one_gate = (t == 0).astype(jnp.int32) * v0        # odd bit on slot 0
            weight = (two_gate + one_gate) * sak              # (M,)
            return acc + weight[:, None] * bk[None, :], None

        acc, _ = lax.scan(slot, acc, jnp.arange(L2))
        return acc, None

    out0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    out, _ = lax.scan(outer_step, out0, jnp.arange(K))
    return out, K * L2


@partial(jax.jit, static_argnames=("bits",))
def ugemm_stream_scan(a: jax.Array, b: jax.Array, bits: int):
    """One-slot-per-step scan reference for :func:`ugemm_stream`."""
    temporal, rate, L = _unified_streams(bits)
    V = vmax(bits)
    pa = jnp.abs(a.astype(jnp.int32)).astype(jnp.float32) / V
    pb = jnp.abs(b.astype(jnp.int32)).astype(jnp.float32) / V
    sgn_a = jnp.sign(a.astype(jnp.float32))
    sgn_b = jnp.sign(b.astype(jnp.float32))

    def body(acc, t):
        at = (temporal[t] < pa).astype(jnp.float32) * sgn_a   # (M, K)
        bt = (rate[t] < pb).astype(jnp.float32) * sgn_b        # (K, N)
        return acc + jnp.matmul(at, bt), None

    acc0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    acc, _ = lax.scan(body, acc0, jnp.arange(L))
    return acc * (V * V / L), L


# ---------------------------------------------------------------------------
# Dispatch (deprecated shims)
#
# The string-keyed dispatch functions below predate the typed backend API in
# ``repro.backends``; they are kept as thin delegating shims so paper-table
# consumers keep working unchanged.  Each emits a DeprecationWarning exactly
# once per process and returns bit-identical results to the replacement call.
# ---------------------------------------------------------------------------

_DEPRECATION_EMITTED: set[str] = set()


def _warn_once(fn_name: str, replacement: str) -> None:
    if fn_name in _DEPRECATION_EMITTED:
        return
    _DEPRECATION_EMITTED.add(fn_name)
    warnings.warn(
        f"repro.core.gemm_sims.{fn_name} is deprecated; use {replacement} "
        f"(see docs/BACKENDS.md for the migration table)",
        DeprecationWarning, stacklevel=3)


def gemm(design: str, a: jax.Array, b: jax.Array, bits: int = 8) -> jax.Array:
    """Deprecated: use ``repro.backends.resolve(design, bits=...).execute``.

    Fast functional GEMM under the chosen unit design.  Args: ``design`` —
    registered name; ``a`` (M, K) / ``b`` (K, N) quantized int codes;
    ``bits`` — their bit-width w.  Returns: (M, N) output — int32 for the
    exact designs, float32 estimate for stochastic uGEMM.
    """
    _warn_once("gemm", "repro.backends.resolve(design, bits=bits).execute(a, b)")
    from repro import backends
    return backends.resolve(design, bits=bits).execute(a, b)


def stream_gemm(design: str, a: jax.Array, b: jax.Array, bits: int = 8):
    """Deprecated: use ``repro.backends.resolve(design, bits=...).stream``.

    Cycle-faithful stream simulation under the chosen unit design.  Returns
    ``(out, cycles)`` — the unit's output plus the clock cycles the schedule
    takes (== ``wc_cycles`` for the worst-case schedules simulated here).
    """
    _warn_once("stream_gemm",
               "repro.backends.resolve(design, bits=bits).stream(a, b)")
    from repro import backends
    return backends.resolve(design, bits=bits).stream(a, b)


@partial(jax.jit, static_argnames=("design", "bits"))
def _gemm_batched_jit(design: str, a: jax.Array, b: jax.Array, bits: int):
    from repro import backends
    return backends.resolve(design, bits=bits).execute(a, b)


def gemm_batched(design: str, a: jax.Array, b: jax.Array,
                 bits: int = 8) -> jax.Array:
    """Deprecated: use ``repro.backends.resolve(design, bits=...).execute``.

    Batched fast functional GEMM, one jit per (design, bits) as before the
    deprecation.  ``a``: (B, M, K) (or (M, K), which falls through to the
    2-D path); ``b``: (B, K, N) per-problem operands, or (K, N) shared
    across the batch (the weight-stationary serving case).
    """
    _warn_once("gemm_batched",
               "repro.backends.resolve(design, bits=bits).execute(a, b)")
    return _gemm_batched_jit(design, a, b, bits)


# ---------------------------------------------------------------------------
# Built-in designs (paper §II)
# ---------------------------------------------------------------------------

register_design(
    "ugemm",
    exact_fn=lambda a, b, bits: ugemm_exact(a, b, bits=bits),
    stream_fn=lambda a, b, bits: ugemm_stream(a, b, bits),
    wc_cycles_fn=lambda bits, common_dim: 2 ** bits,
    exact=False,   # stochastic multiplier: estimate, not the int32 oracle
)

register_design(
    "tugemm",
    exact_fn=lambda a, b, bits: tugemm_exact(a, b),
    stream_fn=lambda a, b, bits: tugemm_stream(a, b, bits),
    wc_cycles_fn=lambda bits, common_dim: common_dim * (2 ** (bits - 1)) ** 2,
    sparsity_aware=True,
    dyn_operand_fn=_tugemm_dyn,
)

register_design(
    "tubgemm",
    exact_fn=lambda a, b, bits: tubgemm_exact(a, b),
    stream_fn=lambda a, b, bits: tubgemm_stream(a, b, bits),
    wc_cycles_fn=lambda bits, common_dim: common_dim * 2 ** (bits - 2),
    sparsity_aware=True,
    dyn_operand_fn=_tubgemm_dyn,
)

register_design(
    "bgemm",
    exact_fn=lambda a, b, bits: bgemm_exact(a, b),
    stream_fn=lambda a, b, bits: (bgemm_exact(a, b), a.shape[1]),
    wc_cycles_fn=lambda bits, common_dim: common_dim,
)
