"""Hardware-in-the-loop energy/latency accounting for model execution.

The paper prices a *single* GEMM unit; a real DLA runs a model as thousands of
tiled GEMM invocations.  This module walks a model's GEMM workload — produced
by the modeling layer via `GemmWorkloadRecorder` — and prices every matmul on
a chosen unit design with its *measured* weight bit sparsity (Eq. 1), giving
end-to-end per-token / per-batch energy, latency and an energy-per-MAC view.

This is the "extend Table V + Fig. 3 to whole models" machinery: the paper
profiles weights and plugs average sparsity into a 32x32 unit; we price each
layer with its own block-max sparsity and the actual tile counts.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import ppa
from repro.core.sparsity import SparsityStats

__all__ = ["GemmCall", "GemmWorkloadRecorder", "ModelCost", "GridCost",
           "PackedStoreReport", "packed_store_report", "price_workload"]


@dataclasses.dataclass(frozen=True)
class GemmCall:
    """One logical matmul: (m, k) @ (k, n_out), with the weight on the k side."""

    name: str
    m: int
    k: int
    n_out: int
    bit_sparsity: float = 0.0   # block-max stat of the temporal (weight) operand
    count: int = 1              # identical invocations (e.g. scanned layers)

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n_out * self.count


class GemmWorkloadRecorder:
    """Collects GemmCalls during an abstract forward pass."""

    def __init__(self) -> None:
        self.calls: list[GemmCall] = []

    def record(self, name: str, m: int, k: int, n_out: int,
               bit_sparsity: float = 0.0, count: int = 1) -> None:
        self.calls.append(GemmCall(name, int(m), int(k), int(n_out),
                                   float(bit_sparsity), int(count)))

    def attach_sparsity(self, stats: dict[str, SparsityStats]) -> None:
        """Overwrite per-call sparsity from profiled weight stats by name."""
        updated = []
        for c in self.calls:
            s = stats.get(c.name)
            if s is not None:
                c = dataclasses.replace(c, bit_sparsity=s.bit_blockmax)
            updated.append(c)
        self.calls = updated


@dataclasses.dataclass(frozen=True)
class ModelCost:
    """Priced workload on one DLA configuration."""

    design: str
    bits: int
    unit_n: int
    num_units: int
    total_macs: int
    wc_latency_us: float
    dyn_latency_us: float
    wc_energy_uj: float
    dyn_energy_uj: float
    per_layer: dict[str, tuple[float, float]]  # name -> (dyn_us, dyn_uj)

    @property
    def energy_per_mac_pj(self) -> float:
        return self.dyn_energy_uj * 1e6 / max(self.total_macs, 1)

    @property
    def sparsity_saving(self) -> float:
        """Fractional energy saved by Eq. 1 vs worst case."""
        if self.wc_energy_uj == 0:
            return 0.0
        return 1.0 - self.dyn_energy_uj / self.wc_energy_uj


@dataclasses.dataclass(frozen=True)
class GridCost(ModelCost):
    """A :class:`ModelCost` priced on a ``units_x`` × ``units_y`` grid of
    DLA nodes (``repro.core.ppa.GridDLAModel`` tiling).

    Extra fields over the single-node cost: the grid shape, the interconnect
    share of the dynamic totals (``hop_energy_uj`` / ``hop_latency_us``, also
    folded into ``dyn_*``/``wc_*``), and ``utilization`` — the MAC-weighted
    mean useful/padded ratio across the workload (1.0 when every contraction
    divides the grid evenly).  Downstream consumers that only understand
    ``ModelCost`` (sweet-spot ranking, serve cost tables) keep working: a
    grid prices as one bigger, hop-taxed DLA.
    """

    units_x: int = 1
    units_y: int = 1
    hop_energy_uj: float = 0.0
    hop_latency_us: float = 0.0
    utilization: float = 1.0

    @property
    def grid(self) -> tuple[int, int]:
        return (self.units_x, self.units_y)

    @property
    def hop_energy_share(self) -> float:
        """Fraction of the dynamic energy spent on chip-to-chip links."""
        if self.dyn_energy_uj == 0:
            return 0.0
        return self.hop_energy_uj / self.dyn_energy_uj


def price_workload(calls: list[GemmCall], design="tubgemm",
                   bits: int = 4, unit_n: int = 128,
                   num_units: int = 1, grid=None) -> ModelCost:
    """Price ``calls`` on a DLA built from ``design`` at ``bits`` width.

    ``design`` is a name or a ``repro.backends.GemmBackend`` (whose own
    ``bits`` / ``pricing_design`` then win): Pallas mirrors price as their
    simulator sibling, uncalibrated designs fail in ppa with a clear
    "no PPA calibration" error.

    ``grid`` — optional ``(units_x, units_y)`` tensor-parallel grid of DLA
    nodes; a ``repro.backends.grid.GridBackend`` supplies its own grid shape.
    With a non-trivial grid the result is a :class:`GridCost` priced on the
    ``ppa.GridDLAModel`` sharded tiling (per-shard tile counts plus the
    interconnect hop terms).
    """
    from repro import backends
    backend = (design if isinstance(design, backends.GemmBackend)
               else backends.resolve(design, bits=bits))
    if grid is None:
        grid = getattr(backend, "grid", None)
    design, bits = backend.pricing_design, backend.bits
    # Stream-coded backends price as their pricing design with a per-tile
    # cycle multiplier (stream_len / 2^bits); 1.0 for everything else.
    cycle_scale = float(getattr(backend, "cycle_scale", 1.0))
    if grid is not None:
        return _price_grid(calls, design, bits, unit_n, num_units,
                           int(grid[0]), int(grid[1]),
                           cycle_scale=cycle_scale)
    dla = ppa.DLAModel(design=design, bits=bits, n=unit_n,
                       num_units=num_units, cycle_scale=cycle_scale)
    wc_ns = dyn_ns = wc_nj = dyn_nj = 0.0
    per_layer: dict[str, tuple[float, float]] = {}
    macs = 0
    for c in calls:
        l_wc = dla.matmul_latency_ns(c.m, c.k, c.n_out, 0.0) * c.count
        l_dyn = dla.matmul_latency_ns(c.m, c.k, c.n_out, c.bit_sparsity) * c.count
        e_wc = dla.matmul_energy_nj(c.m, c.k, c.n_out, 0.0) * c.count
        e_dyn = dla.matmul_energy_nj(c.m, c.k, c.n_out, c.bit_sparsity) * c.count
        wc_ns += l_wc
        dyn_ns += l_dyn
        wc_nj += e_wc
        dyn_nj += e_dyn
        prev = per_layer.get(c.name, (0.0, 0.0))
        per_layer[c.name] = (prev[0] + l_dyn * 1e-3, prev[1] + e_dyn * 1e-3)
        macs += c.macs
    return ModelCost(
        design=design, bits=bits, unit_n=unit_n, num_units=num_units,
        total_macs=macs,
        wc_latency_us=wc_ns * 1e-3, dyn_latency_us=dyn_ns * 1e-3,
        wc_energy_uj=wc_nj * 1e-3, dyn_energy_uj=dyn_nj * 1e-3,
        per_layer=per_layer,
    )


@dataclasses.dataclass(frozen=True)
class PackedStoreReport:
    """Weight-HBM footprint of a (possibly partially) bit-packed tree.

    The "bits as bytes" companion to the Eq.-1 energy tables: packing the
    planned sites at their assigned widths cuts the weight bytes a decode
    step streams from HBM by 4–16x (int32 words, 32/bits codes per word)
    while the integer arithmetic — and hence the energy/latency evidence —
    is bit-identical.  ``float32_bytes`` counts every weight leaf at fp32;
    ``stored_bytes`` counts packed leaves at their word+scale footprint and
    unpacked leaves at fp32, so ``reduction`` is the end-to-end factor on
    the whole store and ``packed_reduction`` the factor on just the packed
    sites.
    """

    float32_bytes: int
    stored_bytes: int
    packed_sites: int
    total_sites: int
    packed_float32_bytes: int
    packed_stored_bytes: int

    @property
    def reduction(self) -> float:
        return self.float32_bytes / max(self.stored_bytes, 1)

    @property
    def packed_reduction(self) -> float:
        return self.packed_float32_bytes / max(self.packed_stored_bytes, 1)


def packed_store_report(params) -> PackedStoreReport:
    """Walk ``params`` and total the weight-store bytes (packed vs fp32).

    Counts every array leaf with ``ndim >= 1``; ``total_sites`` is the
    number of ``ndim >= 2`` leaves (the GEMM-shaped ones a plan can pack).
    """
    import jax

    from repro.core import packing

    f32 = stored = 0
    packed_sites = total_sites = 0
    packed_f32 = packed_stored = 0
    leaves = jax.tree_util.tree_leaves(params, is_leaf=packing.is_packed)
    for leaf in leaves:
        if packing.is_packed(leaf):
            f32 += leaf.float32_bytes
            stored += leaf.stored_bytes
            packed_f32 += leaf.float32_bytes
            packed_stored += leaf.stored_bytes
            packed_sites += 1
            total_sites += 1
            continue
        if not hasattr(leaf, "ndim"):
            continue
        nbytes = int(leaf.size) * 4
        f32 += nbytes
        stored += nbytes
        if leaf.ndim >= 2:
            total_sites += 1
    return PackedStoreReport(
        float32_bytes=f32, stored_bytes=stored,
        packed_sites=packed_sites, total_sites=total_sites,
        packed_float32_bytes=packed_f32, packed_stored_bytes=packed_stored)


def _price_grid(calls: list[GemmCall], design: str, bits: int, unit_n: int,
                num_units: int, units_x: int, units_y: int, *,
                cycle_scale: float = 1.0) -> GridCost:
    """The grid branch of :func:`price_workload` (same contract)."""
    gdla = ppa.GridDLAModel(design=design, bits=bits, n=unit_n,
                            num_units=num_units, units_x=units_x,
                            units_y=units_y, cycle_scale=cycle_scale)
    wc_ns = dyn_ns = wc_nj = dyn_nj = hop_nj = hop_ns = 0.0
    per_layer: dict[str, tuple[float, float]] = {}
    macs = padded_macs = 0
    for c in calls:
        l_wc = gdla.matmul_latency_ns(c.m, c.k, c.n_out, 0.0) * c.count
        l_dyn = gdla.matmul_latency_ns(c.m, c.k, c.n_out,
                                       c.bit_sparsity) * c.count
        e_wc = gdla.matmul_energy_nj(c.m, c.k, c.n_out, 0.0) * c.count
        e_dyn = gdla.matmul_energy_nj(c.m, c.k, c.n_out,
                                      c.bit_sparsity) * c.count
        hop_nj += gdla.hop_energy_nj(c.m, c.k, c.n_out) * c.count
        hop_ns += gdla.hop_latency_ns() * c.count
        wc_ns += l_wc
        dyn_ns += l_dyn
        wc_nj += e_wc
        dyn_nj += e_dyn
        prev = per_layer.get(c.name, (0.0, 0.0))
        per_layer[c.name] = (prev[0] + l_dyn * 1e-3, prev[1] + e_dyn * 1e-3)
        macs += c.macs
        ks, ns = gdla.shard_dims(c.k, c.n_out)
        padded_macs += c.m * ks * units_x * ns * units_y * c.count
    return GridCost(
        design=design, bits=bits, unit_n=unit_n, num_units=num_units,
        total_macs=macs,
        wc_latency_us=wc_ns * 1e-3, dyn_latency_us=dyn_ns * 1e-3,
        wc_energy_uj=wc_nj * 1e-3, dyn_energy_uj=dyn_nj * 1e-3,
        per_layer=per_layer,
        units_x=units_x, units_y=units_y,
        hop_energy_uj=hop_nj * 1e-3, hop_latency_us=hop_ns * 1e-3,
        utilization=(macs / padded_macs) if padded_macs else 1.0,
    )
