"""AdamW + schedules + clipping, built from scratch (no optax).

Supports bf16 first/second-moment storage (halves optimizer HBM — required to
fit the 671B config on 16 GB/chip at 512 ways) and composes with the int8
gradient-compression hook in ``optim.compression``.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm",
    "cosine_schedule", "linear_schedule", "constant_schedule",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    state_dtype: str = "float32"        # "bfloat16" halves m/v memory
    # int8 gradient compression with error feedback (optim.compression)
    compress_grads: bool = False


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OptState:
    step: jax.Array
    m: dict
    v: dict
    ef: dict | None = None              # error-feedback residuals

    def tree_flatten(self):
        return (self.step, self.m, self.v, self.ef), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        step, m, v, ef = children
        return cls(step=step, m=m, v=v, ef=ef)


def _state_dtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    dt = _state_dtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    ef = None
    if cfg.compress_grads:
        ef = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params),
                    ef=ef)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig,
                 lr: jax.Array | float):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.compress_grads and state.ef is not None:
        from repro.optim.compression import compress_with_error_feedback
        grads, new_ef = compress_with_error_feedback(grads, state.ef)
    else:
        new_ef = state.ef

    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    metrics["grad_norm"] = gnorm

    step = state.step + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    dt = _state_dtype(cfg)

    def upd(p, g, m, v):
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    # unzip the 3-tuples
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v, ef=new_ef), metrics


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return f


def linear_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, base_lr * (1 - prog))
    return f


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.full((), base_lr, jnp.float32)
