"""Optimizer substrate: AdamW (bf16-state option), schedules, clipping,
int8 gradient compression with error feedback."""

from repro.optim.compression import compress_with_error_feedback, int8_psum
from repro.optim.optimizer import (AdamWConfig, OptState, adamw_init,
                                   adamw_update, clip_by_global_norm,
                                   constant_schedule, cosine_schedule,
                                   global_norm, linear_schedule)

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm",
    "cosine_schedule", "linear_schedule", "constant_schedule",
    "compress_with_error_feedback", "int8_psum",
]
