"""Gradient compression: int8 quantization with error feedback, and an
explicit compressed data-parallel all-reduce for shard_map training steps.

Error feedback (Seide et al. / EF-SGD): the quantization residual is carried
into the next step, so compression bias vanishes asymptotically — standard
practice for production gradient compression.

Two integration points:
  * ``compress_with_error_feedback`` — numerics-only hook inside the optimizer
    (models the end-to-end effect; used on any backend).
  * ``int8_psum`` — a shard_map collective that all-reduces int8-quantized
    gradients over the data axis (4x wire-bytes reduction vs f32; visible in
    the dry-run HLO as an int32 all-reduce of quarter-width payload).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compress_with_error_feedback",
           "int8_psum"]


def quantize_int8(g: jax.Array):
    """Per-tensor symmetric int8.  Returns (codes, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compress_with_error_feedback(grads, ef):
    """Quantize each grad tensor to int8, carrying the residual in ``ef``."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        codes, scale = quantize_int8(g32)
        deq = dequantize_int8(codes, scale)
        return deq, g32 - deq

    out = jax.tree_util.tree_map(one, grads, ef)
    new_grads = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef


def int8_psum(grads, mesh, axis: str = "data"):
    """All-reduce a gradient pytree over ``axis`` with int8 payloads.

    Each rank quantizes per-tensor to int8; codes are summed in int32 (exact),
    scales are max-reduced, and the result is dequantized — 4x less wire
    traffic than an f32 psum at <1% relative error for typical grads.
    """

    def block(*leaves):
        outs = []
        for g in leaves:
            g32 = g.astype(jnp.float32)
            # shared scale (pmax) so codes are comparable across ranks
            amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            codes = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
            summed = jax.lax.psum(codes, axis)
            outs.append(summed.astype(jnp.float32) * scale)
        return tuple(outs)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    specs = tuple(P() for _ in leaves)
    fn = shard_map(block, mesh=mesh, in_specs=specs, out_specs=specs,
                       check_vma=False)
    out = fn(*leaves)
    return jax.tree_util.tree_unflatten(treedef, out)
