"""Finding records shared by the three ``repro.analysis`` passes.

A finding is one diagnostic emitted by a pass: the pass that produced it,
a stable rule identifier (what went wrong), a severity, a location (a GEMM
site, a plan entry, or a ``file:line``) and a human-readable message.

Severity semantics follow compiler convention:

* ``error`` — the property the pass proves is violated (an accumulator can
  overflow, a plan entry can never match, forbidden registry mutation).
  Any error makes the CLI exit non-zero; CI treats errors as gate failures.
* ``warning`` — advisory: legal but worth a look (a guard-relaxed plan
  entry, a weight GEMM the planner cannot see).  Warnings are printed but
  do not fail the gate.

This module is dependency-free on purpose: every pass (and the runtime
guards in ``repro.backends``) can import it without pulling in JAX or the
backend stack.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

ERROR = "error"
WARNING = "warning"
_SEVERITIES = (ERROR, WARNING)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from an analysis pass."""

    pass_name: str  # "ranges" | "plan-lint" | "source-lint"
    rule: str       # stable kebab-case rule id, e.g. "acc-overflow"
    severity: str   # ERROR or WARNING
    where: str      # site name, plan entry pattern, or file:line
    message: str

    def __post_init__(self) -> None:
        if self.severity not in _SEVERITIES:
            raise ValueError(f"severity must be one of {_SEVERITIES}, "
                             f"got {self.severity!r}")

    def render(self) -> str:
        return (f"[{self.pass_name}] {self.severity} {self.rule} "
                f"at {self.where}: {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def errors(findings: Iterable[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == ERROR]


def warnings_(findings: Iterable[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == WARNING]


def exit_code(findings: Sequence[Finding]) -> int:
    """CLI/CI contract: non-zero iff any error-severity finding."""
    return 1 if errors(findings) else 0


def verdict_line(findings: Sequence[Finding]) -> str:
    """One-line summary, printed by serve and the benchmark reports."""
    n_err = len(errors(findings))
    n_warn = len(warnings_(findings))
    if not n_err and not n_warn:
        return "analysis: OK (0 findings)"
    return f"analysis: {n_err} error(s), {n_warn} warning(s)"
