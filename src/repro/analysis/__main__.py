"""``python -m repro.analysis`` — run every static pass; exit 1 on errors.

Default scope (the CI gate):

* **ranges** — every registered model config (``repro.configs.ARCH_IDS``,
  the *full* published configs, traced abstractly so no weight is ever
  materialized): GEMM-site discovery, jaxpr cross-check, and the
  accumulator-envelope sweep over the paper's designs x bit-widths,
  including per-shard K splits for representative grid geometries.
* **plan-lint** — every shipped plan document (``examples/plans/*.json``)
  plus any ``--plan`` paths.
* **source-lint** — the repo's non-test python (``src``, ``benchmarks``,
  ``examples``, ``tools``).

Warnings are printed but only error-severity findings fail the gate (see
``repro.analysis.findings``).  ``--json`` dumps the findings for tooling.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis import findings as findings_lib
from repro.analysis.findings import Finding


def _repo_root() -> pathlib.Path:
    # src/repro/analysis/__main__.py -> repo root is three parents up
    # from the package directory; fall back to cwd when installed flat.
    candidate = pathlib.Path(__file__).resolve().parents[3]
    return candidate if (candidate / "src").is_dir() else pathlib.Path.cwd()


def _run_ranges(archs, grids) -> tuple[list[Finding], list[str]]:
    from repro import configs
    from repro.analysis import jaxpr_scan

    out: list[Finding] = []
    lines: list[str] = []
    for arch in archs:
        cfg = configs.get_config(arch)
        fs, stats = jaxpr_scan.check_model(cfg, arch=arch, grids=grids)
        out.extend(fs)
        lines.append(
            f"  ranges: {arch}: {stats['sites']} sites, "
            f"{stats['dot_generals']} dot_generals, "
            f"{stats['points_checked']} envelope points")
    return out, lines


def _run_plan_lint(paths) -> tuple[list[Finding], list[str]]:
    from repro.analysis import plan_lint

    out: list[Finding] = []
    lines: list[str] = []
    for path in paths:
        fs = plan_lint.lint_plan_file(path)
        out.extend(fs)
        lines.append(f"  plan-lint: {path}")
    return out, lines


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static numeric-safety, plan-lint and source-lint "
                    "passes over the backend/plan/grid stack.")
    parser.add_argument("--arch", action="append", default=None,
                        help="restrict the ranges pass to this arch id "
                             "(repeatable; default: all registered)")
    parser.add_argument("--plan", action="append", default=None,
                        type=pathlib.Path,
                        help="additional plan JSON to lint (repeatable)")
    parser.add_argument("--grid", action="append", default=None,
                        help="grid geometry UXxUY for per-shard K splits "
                             "(repeatable; default: 1x1, 2x2, 4x1)")
    parser.add_argument("--root", type=pathlib.Path, default=None,
                        help="repo root (default: auto-detected)")
    parser.add_argument("--skip-ranges", action="store_true")
    parser.add_argument("--skip-plans", action="store_true")
    parser.add_argument("--skip-source", action="store_true")
    parser.add_argument("--json", type=pathlib.Path, default=None,
                        help="write findings as JSON to this path")
    args = parser.parse_args(argv)

    root = args.root or _repo_root()
    findings: list[Finding] = []
    narration: list[str] = []

    if not args.skip_ranges:
        from repro import configs
        archs = args.arch or list(configs.ARCH_IDS)
        unknown = [a for a in archs if a not in configs.ARCH_IDS]
        if unknown:
            parser.error(f"unknown arch id(s): {unknown} "
                         f"(registered: {list(configs.ARCH_IDS)})")
        if args.grid:
            grids = []
            for g in args.grid:
                ux, _, uy = g.partition("x")
                grids.append((int(ux), int(uy)))
        else:
            grids = [(1, 1), (2, 2), (4, 1)]
        fs, lines = _run_ranges(archs, grids)
        findings.extend(fs)
        narration.extend(lines)

    if not args.skip_plans:
        plans = sorted((root / "examples" / "plans").glob("*.json"))
        plans.extend(args.plan or [])
        fs, lines = _run_plan_lint(plans)
        findings.extend(fs)
        narration.extend(lines)

    if not args.skip_source:
        from repro.analysis import source_lint
        findings.extend(source_lint.lint_repo(root))
        narration.append(f"  source-lint: {root}")

    for line in narration:
        print(line)
    for f in findings:
        print(f.render())
    print(findings_lib.verdict_line(findings))

    if args.json:
        args.json.write_text(json.dumps(
            {"findings": [f.to_json() for f in findings],
             "verdict": findings_lib.verdict_line(findings)}, indent=2))
    return findings_lib.exit_code(findings)


if __name__ == "__main__":
    sys.exit(main())
