"""Repo-specific AST lint rules for the backend stack's contracts.

Four rules, each encoding an invariant the rest of the codebase relies on
but Python cannot enforce:

* ``registry-mutation`` — the ``gemm_sims`` design registry may only be
  mutated through ``scoped_registry`` / ``kernel_backends`` scopes (or in
  ``core/gemm_sims.py`` itself, which registers the built-ins).  Unscoped
  mutation leaks designs across tests and benchmark snapshots.
* ``deprecated-shim`` — the string-dispatch shims (``gemm_sims.gemm`` /
  ``stream_gemm`` / ``gemm_batched`` and
  ``kernels.backends.register_kernel_backends``) are for tests and
  back-compat only; production paths construct backends with
  ``repro.backends.resolve``.
* ``unjitted-rng`` — ``jax.random`` calls in the execute layer
  (``repro/backends``, ``repro/kernels``) outside a jitted function force
  host synchronization per call on the hot path.
* ``float-accumulation`` — a contraction inside an exact-design kernel
  (``bgemm*``/``tugemm*``/``tubgemm*``/``tu_gemm*``/``tub_gemm*``/
  ``quant_gemm*``) must pass an integer ``preferred_element_type``;
  float32 accumulation silently re-introduces the rounding the designs'
  exactness claim excludes (uGEMM's float-count path is the documented
  exception and is not an exact design).
* ``packed-materialize`` — ``kernels/packed_gemm.py``'s execute paths
  exist so the dequantized weight matrix never materializes; a
  ``dequantize(...)`` call there silently reverts the fused kernel to a
  materialize-then-contract path, undoing the 4–16x HBM-traffic cut the
  packed store is for.

Suppression: a ``# analysis: allow-<rule>`` comment on the flagged line or
on the enclosing ``def`` line disables that rule there (used where a rule's
precondition is satisfied non-lexically, e.g. the registration helper that
is only called under a scope).  Test trees are skipped entirely.
"""

from __future__ import annotations

import ast
import pathlib
import re
from typing import Iterable

from repro.analysis.findings import ERROR, Finding

RULES = ("registry-mutation", "deprecated-shim", "unjitted-rng",
         "float-accumulation", "packed-materialize")

_PRAGMA_RE = re.compile(r"#\s*analysis:\s*allow-([a-z0-9-]+)")

#: Deprecated string-dispatch surface: module -> function names.
DEPRECATED_SHIMS = {
    "repro.core.gemm_sims": {"gemm", "stream_gemm", "gemm_batched"},
    "repro.kernels.backends": {"register_kernel_backends"},
}
_REGISTRY_MODULE = "repro.core.gemm_sims"
_REGISTRY_MUTATORS = {"register_design", "registry_restore"}
_SCOPE_MANAGERS = {"scoped_registry", "kernel_backends"}

_EXECUTE_PATH_PARTS = ("repro/backends/", "repro/kernels/", "repro/serving/")
_PACKED_KERNEL_PARTS = ("kernels/packed_gemm",)
_EXACT_KERNEL_PREFIXES = ("bgemm", "tugemm", "tubgemm", "tu_gemm",
                          "tub_gemm", "quant_gemm",
                          "fused_paged", "_fused_decode")
_CONTRACTION_FUNCS = {"einsum", "matmul", "dot", "dot_general", "tensordot"}
_INT_DTYPES = {"int8", "int16", "int32", "int64"}

#: Files whose job is to define the things the rules police.
_DEFINING_FILES = {
    "registry-mutation": ("src/repro/core/gemm_sims.py",),
    "deprecated-shim": ("src/repro/core/gemm_sims.py",
                        "src/repro/kernels/backends.py"),
}


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chains as a dotted string (None otherwise)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_jit(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "jit":
            return True
        if isinstance(sub, ast.Name) and sub.id == "jit":
            return True
    return False


class _FileLint(ast.NodeVisitor):
    def __init__(self, path: pathlib.Path, rel: str, source: str):
        self.rel = rel
        self.findings: list[Finding] = []
        self.pragmas: dict[int, set[str]] = {}
        for i, line in enumerate(source.splitlines(), start=1):
            for m in _PRAGMA_RE.finditer(line):
                self.pragmas.setdefault(i, set()).add(m.group(1))
        # import resolution: alias -> module path, name -> (module, attr)
        self.module_alias: dict[str, str] = {}
        self.from_import: dict[str, tuple[str, str]] = {}
        self.func_stack: list[ast.AST] = []
        self.scope_with_depth = 0  # inside `with ...scoped_registry():`
        self.in_execute_path = any(p in rel for p in _EXECUTE_PATH_PARTS)

    # -- plumbing ---------------------------------------------------------
    def _allowed(self, rule: str, line: int) -> bool:
        if rule in self.pragmas.get(line, ()):
            return True
        return any(rule in self.pragmas.get(f.lineno, ())
                   for f in self.func_stack)

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        if self._allowed(rule, node.lineno):
            return
        self.findings.append(Finding(
            pass_name="source-lint", rule=rule, severity=ERROR,
            where=f"{self.rel}:{node.lineno}", message=message))

    def _resolve(self, chain: str) -> str:
        """Expand the chain's leading alias to its imported module path."""
        head, _, rest = chain.partition(".")
        base = self.module_alias.get(head)
        if base is not None:
            return f"{base}.{rest}" if rest else base
        return chain

    # -- imports ----------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.asname:
                self.module_alias[alias.asname] = alias.name
            else:
                top = alias.name.partition(".")[0]
                self.module_alias.setdefault(top, top)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for alias in node.names:
            bound = alias.asname or alias.name
            self.from_import[bound] = (mod, alias.name)
            self.module_alias[bound] = f"{mod}.{alias.name}"
        self.generic_visit(node)

    # -- structure --------------------------------------------------------
    def _visit_func(self, node) -> None:
        self.func_stack.append(node)
        self.generic_visit(node)
        self.func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_With(self, node: ast.With) -> None:
        scoped = any(
            isinstance(item.context_expr, ast.Call)
            and (_dotted(item.context_expr.func) or "").rpartition(".")[2]
            in _SCOPE_MANAGERS
            for item in node.items)
        self.scope_with_depth += scoped
        self.generic_visit(node)
        self.scope_with_depth -= scoped

    # -- the rules --------------------------------------------------------
    def _call_target(self, node: ast.Call) -> tuple[str, str] | None:
        """(module, function) a call resolves to, best-effort."""
        if isinstance(node.func, ast.Name):
            hit = self.from_import.get(node.func.id)
            if hit:
                return hit
            return None
        chain = _dotted(node.func)
        if chain is None:
            return None
        full = self._resolve(chain)
        mod, _, fn = full.rpartition(".")
        return (mod, fn) if mod else None

    def visit_Call(self, node: ast.Call) -> None:
        target = self._call_target(node)
        if target is not None:
            mod, fn = target
            if mod in DEPRECATED_SHIMS and fn in DEPRECATED_SHIMS[mod] \
                    and not self._exempt("deprecated-shim"):
                self._flag(
                    "deprecated-shim", node,
                    f"call to deprecated {mod}.{fn}; construct backends "
                    f"with repro.backends.resolve instead (migration "
                    f"table in docs/BACKENDS.md)")
            if mod == _REGISTRY_MODULE and fn in _REGISTRY_MUTATORS \
                    and not self.scope_with_depth \
                    and not self._exempt("registry-mutation"):
                self._flag(
                    "registry-mutation", node,
                    f"{fn} mutates the global design registry outside a "
                    f"scoped_registry/kernel_backends scope — leaked "
                    f"registrations outlive the caller")
        if self.in_execute_path:
            chain = _dotted(node.func) or ""
            full = self._resolve(chain)
            if full.startswith("jax.random.") and not self._in_jitted():
                self._flag(
                    "unjitted-rng", node,
                    f"{full} on the execute path outside a jitted "
                    f"function — host-synchronizing RNG per call")
        self._check_accumulation(node)
        self._check_packed_materialize(node)
        self.generic_visit(node)

    def _exempt(self, rule: str) -> bool:
        return self.rel in _DEFINING_FILES.get(rule, ())

    def _in_jitted(self) -> bool:
        return any(_mentions_jit(dec)
                   for f in self.func_stack
                   for dec in getattr(f, "decorator_list", ()))

    def _in_exact_kernel(self) -> str | None:
        for f in reversed(self.func_stack):
            name = getattr(f, "name", "")
            if name.startswith(_EXACT_KERNEL_PREFIXES):
                return name
        return None

    def _check_accumulation(self, node: ast.Call) -> None:
        chain = _dotted(node.func) or ""
        if chain.rpartition(".")[2] not in _CONTRACTION_FUNCS:
            return
        kernel = self._in_exact_kernel()
        if kernel is None:
            return
        for kw in node.keywords:
            if kw.arg == "preferred_element_type":
                dtype = (_dotted(kw.value) or "").rpartition(".")[2]
                if dtype in _INT_DTYPES:
                    return
                break
        self._flag(
            "float-accumulation", node,
            f"contraction in exact-design kernel {kernel!r} without an "
            f"integer preferred_element_type — partial sums would "
            f"accumulate in float, voiding the bit-exactness claim")

    def _check_packed_materialize(self, node: ast.Call) -> None:
        if not any(p in self.rel for p in _PACKED_KERNEL_PARTS):
            return
        chain = _dotted(node.func) or ""
        if chain.rpartition(".")[2] != "dequantize":
            return
        self._flag(
            "packed-materialize", node,
            "dequantize(...) inside the packed-GEMM kernel module — the "
            "fused execute path must contract int32-word tiles directly; "
            "materializing the dequantized matrix reverts the packed "
            "store's HBM-traffic saving")

    def _registry_store(self, node: ast.AST) -> None:
        chain = _dotted(node) or (node.id if isinstance(node, ast.Name)
                                  else "")
        if isinstance(node, ast.Subscript):
            chain = _dotted(node.value) or ""
        if self._resolve(chain).rpartition(".")[2] == "_REGISTRY" \
                and not self.scope_with_depth \
                and not self._exempt("registry-mutation"):
            self._flag(
                "registry-mutation", node,
                "direct write to gemm_sims._REGISTRY — use "
                "register_design under a scoped_registry scope")

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._registry_store(tgt)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._registry_store(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            self._registry_store(tgt)
        self.generic_visit(node)


def lint_source(source: str, *, rel: str = "<memory>") -> list[Finding]:
    """Lint one file's text (unit-test entry point)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(pass_name="source-lint", rule="syntax-error",
                        severity=ERROR, where=f"{rel}:{e.lineno or 0}",
                        message=str(e))]
    lint = _FileLint(pathlib.Path(rel), rel, source)
    lint.visit(tree)
    return lint.findings


def _is_test_path(rel: str) -> bool:
    parts = pathlib.PurePosixPath(rel).parts
    return any(p in ("tests", "test") or p.startswith("test_")
               for p in parts)


def iter_python_files(root: pathlib.Path,
                      subdirs: Iterable[str]) -> Iterable[pathlib.Path]:
    for sub in subdirs:
        base = root / sub
        if base.is_file() and base.suffix == ".py":
            yield base
            continue
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            yield p


def lint_repo(root, subdirs: Iterable[str] = ("src", "benchmarks",
                                              "examples", "tools")
              ) -> list[Finding]:
    """Lint every non-test python file under the given repo subtrees."""
    root = pathlib.Path(root)
    findings: list[Finding] = []
    for path in iter_python_files(root, subdirs):
        rel = path.relative_to(root).as_posix()
        if _is_test_path(rel):
            continue
        findings.extend(lint_source(path.read_text(), rel=rel))
    return findings
