"""Static analysis gating the backend/plan/grid stack.

Three passes, one CLI (``python -m repro.analysis``, non-zero exit on
error findings):

* :mod:`repro.analysis.ranges` + :mod:`repro.analysis.jaxpr_scan` — the
  numeric-range verifier: interval arithmetic over worst-case accumulator
  magnitudes per design, applied to every GEMM site a zero-FLOP
  ``jax.eval_shape`` trace discovers, cross-checked against the model
  jaxpr's ``dot_general`` population.
* :mod:`repro.analysis.plan_lint` — static checks on ``BackendPlan`` /
  ``GridPlan`` JSON (unknown designs, dead/shadowed patterns, uncovered
  sites, guard relaxations, overflow-hazardous assignments).
* :mod:`repro.analysis.source_lint` — repo-specific AST rules (registry
  mutation outside ``scoped_registry``, deprecated shim calls, unjitted
  RNG in execute paths, float-accumulating exact-design kernels).

This package ``__init__`` stays import-light: ``repro.backends.base``
imports :mod:`repro.analysis.ranges` for its runtime envelope guard, so
eagerly importing the lint passes here (which import ``repro.backends``)
would create a cycle.  Submodules load lazily on attribute access.
"""

from __future__ import annotations

import importlib

from repro.analysis.findings import (  # noqa: F401  (re-export)
    ERROR, Finding, WARNING, errors, exit_code, verdict_line,
)

_SUBMODULES = ("findings", "ranges", "jaxpr_scan", "plan_lint",
               "source_lint")

__all__ = ["ERROR", "WARNING", "Finding", "errors", "exit_code",
           "verdict_line", *_SUBMODULES]


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
