"""Zero-FLOP GEMM discovery and jaxpr cross-check for the range verifier.

Two independent views of a model's GEMM population are reconciled here:

* the **planner's view** — ``repro.backends.record_sites()`` under a
  ``jax.eval_shape`` trace (what ``models/common.dense`` would contract on
  a backend), via ``repro.eval.planner.discover_sites``;
* the **compiler's view** — every ``dot_general`` equation in the model's
  jaxpr, collected by recursively walking sub-jaxprs (scan/pjit/cond
  bodies) of a ``jax.make_jaxpr`` trace.

Parameter provenance is tracked through shape-preserving ops, so each
``dot_general`` that consumes a weight leaf directly can be attributed to
its parameter path.  The cross-check then proves (a) every recorded site
actually executes as a matching contraction, and (b) flags weight GEMMs
the planner cannot see (e.g. a tied-embedding logits head) — those run on
the float path whatever the plan says, so they are surfaced as warnings.

Both traces are abstract: parameters come from
``jax.eval_shape(init_params, ...)`` (``ShapeDtypeStruct`` leaves), so even
the 671B registered config scans in about a second without materializing a
single weight.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.analysis import ranges
from repro.analysis.findings import ERROR, WARNING, Finding

#: Primitives that carry their (sole) input's identity through unchanged in
#: provenance terms — enough to follow a weight leaf into its dot_general.
_PASS_THROUGH = frozenset({
    "convert_element_type", "transpose", "reshape", "squeeze",
    "expand_dims", "broadcast_in_dim", "slice", "rev", "copy",
    "copy_p", "stop_gradient", "dynamic_slice",
})


@dataclasses.dataclass(frozen=True)
class DotSite:
    """One ``dot_general`` equation, normalized to GEMM terms."""

    lhs_shape: tuple[int, ...]
    rhs_shape: tuple[int, ...]
    k: int                    # contracted size
    n_out: int                # rhs free (non-batch) size
    m: int                    # lhs free (non-batch) size
    batch: int                # product of batch-dim sizes (0 dims -> 1)
    param_path: str | None    # weight-leaf provenance, if either operand
                              # traces back to a parameter leaf

    @property
    def weight_like(self) -> bool:
        return self.param_path is not None


def abstract_params(cfg):
    """The model's parameter tree as ``ShapeDtypeStruct`` leaves (no FLOPs,
    no memory — works for the full published configs)."""
    from repro.models import model as model_lib
    return jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))


def _forward_fn(cfg, batch: int, seq_len: int):
    """The traceable forward closure and its example arguments."""
    from repro.models import model as model_lib

    if getattr(cfg, "frontend_stub", False):
        embeds = jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                      jnp.float32)
        return (lambda p, e: model_lib.forward(p, cfg, embeds=e)[0]), embeds
    tokens = jnp.zeros((batch, seq_len), jnp.int32)
    return (lambda p, t: model_lib.forward(p, cfg, t)[0]), tokens


def _param_paths(params) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]


def _is_var(v) -> bool:
    return not hasattr(v, "val")  # Literals carry .val; Vars do not


def _dot_site(eqn, labels: dict) -> DotSite:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    k = math.prod(rhs.shape[d] for d in rc) if rc else 1
    batch = math.prod(rhs.shape[d] for d in rb) if rb else 1
    n_out = math.prod(s for d, s in enumerate(rhs.shape)
                      if d not in rc and d not in rb)
    m = math.prod(s for d, s in enumerate(lhs.shape)
                  if d not in lc and d not in lb)
    path = None
    for v in eqn.invars[:2]:
        if _is_var(v) and v in labels:
            path = labels[v]
            break
    return DotSite(lhs_shape=tuple(lhs.shape), rhs_shape=tuple(rhs.shape),
                   k=int(k), n_out=int(n_out), m=int(m), batch=int(batch),
                   param_path=path)


def _sub_jaxprs(eqn):
    for val in eqn.params.values():
        inner = getattr(val, "jaxpr", None)
        if inner is not None and hasattr(inner, "eqns"):
            yield inner
        elif hasattr(val, "eqns"):
            yield val
        elif isinstance(val, (tuple, list)):
            for item in val:
                inner = getattr(item, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield inner


def _walk(jaxpr, labels: dict, out: list[DotSite]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            out.append(_dot_site(eqn, labels))
        elif name in _PASS_THROUGH and eqn.invars and eqn.outvars:
            v = eqn.invars[0]
            if _is_var(v) and v in labels:
                labels[eqn.outvars[0]] = labels[v]
        for inner in _sub_jaxprs(eqn):
            # Positional invar mapping holds for pjit/closed_call/scan
            # (consts-then-args order); bodies with a different calling
            # convention are still walked, just without provenance.
            inner_labels = {}
            if len(inner.invars) == len(eqn.invars):
                for outer_v, inner_v in zip(eqn.invars, inner.invars):
                    if _is_var(outer_v) and outer_v in labels:
                        inner_labels[inner_v] = labels[outer_v]
            _walk(inner, inner_labels, out)


def collect_dot_generals(cfg, params=None, *, batch: int = 1,
                         seq_len: int = 8) -> list[DotSite]:
    """Every ``dot_general`` in one forward step's jaxpr, with provenance."""
    if params is None:
        params = abstract_params(cfg)
    fn, example = _forward_fn(cfg, batch, seq_len)
    closed = jax.make_jaxpr(fn)(params, example)
    n_leaves = len(jax.tree_util.tree_leaves(params))
    paths = _param_paths(params)
    labels = {v: paths[i] for i, v in enumerate(closed.jaxpr.invars[:n_leaves])
              if _is_var(v)}
    out: list[DotSite] = []
    _walk(closed.jaxpr, labels, out)
    return out


def cross_check(sites, dots: Sequence[DotSite], *,
                arch: str = "") -> list[Finding]:
    """Reconcile recorded GEMM sites against the jaxpr population.

    * a recorded site with no ``dot_general`` of matching (k, n_out) is an
      error — the site contract claims a contraction the compiled model
      never runs;
    * a weight-consuming ``dot_general`` that matches no recorded site is
      a warning — the planner cannot price or plan it, so it always runs
      on the float path (tied-embedding logits heads land here).
    """
    prefix = f"{arch}/" if arch else ""
    findings: list[Finding] = []
    shapes = {(d.k, d.n_out) for d in dots}
    for site in sites:
        if (site.k, site.n_out) not in shapes:
            findings.append(Finding(
                pass_name="ranges", rule="site-not-in-jaxpr",
                severity=ERROR, where=f"{prefix}{site.name}",
                message=f"recorded GEMM site (k={site.k}, "
                        f"n_out={site.n_out}) has no matching dot_general "
                        f"in the model jaxpr"))
    site_shapes = {(s.k, s.n_out) for s in sites}
    seen: set[str] = set()
    for dot in dots:
        if not dot.weight_like or (dot.k, dot.n_out) in site_shapes:
            continue
        if dot.k == 1 and dot.n_out == 1:
            continue  # degenerate rank-0 contraction (a scalar gate), not a GEMM
        if dot.param_path in seen:
            continue
        seen.add(dot.param_path)
        findings.append(Finding(
            pass_name="ranges", rule="planner-invisible-gemm",
            severity=WARNING, where=f"{prefix}{dot.param_path}",
            message=f"weight leaf contracts as (k={dot.k}, "
                    f"n_out={dot.n_out}) outside any dense site — the "
                    f"planner cannot assign it a backend, so it always "
                    f"runs on the float path"))
    return findings


def range_sweep(sites, *, designs: Sequence[str],
                bits_candidates: Sequence[int],
                grids: Sequence[tuple[int, int]] = ((1, 1),),
                arch: str = "") -> tuple[list[Finding], int]:
    """Prove every (site, design, bits, grid) point's accumulator safe.

    For each discovered site, every candidate design x bit-width is checked
    at the site's full contraction length and at each grid geometry's
    per-shard split (K ceil-split over ``units_x`` — the padded shard K is
    what ``GridBackend.execute`` actually contracts).  An individually
    infeasible combination is a *warning* (the planner prunes it); a site
    where **no** candidate fits any envelope is an error — nothing could
    ever execute it exactly.

    Returns ``(findings, points_checked)``.
    """
    prefix = f"{arch}/" if arch else ""
    findings: list[Finding] = []
    checked = 0
    for site in sites:
        feasible = 0
        for design in designs:
            for bits in bits_candidates:
                for ux, uy in grids:
                    k_shard = -(-site.k // ux)
                    checked += 1
                    where = f"{prefix}{site.name}"
                    if (ux, uy) != (1, 1):
                        where += f" [grid {ux}x{uy}]"
                    f = ranges.check_gemm(design, bits, k_shard, where=where)
                    if f is None:
                        if (ux, uy) == grids[0]:
                            feasible += 1
                    elif f.rule == "acc-overflow":
                        findings.append(dataclasses.replace(
                            f, severity=WARNING,
                            message=f.message + " (planner prunes this "
                                    "candidate)"))
                    else:
                        findings.append(f)
        if not feasible:
            findings.append(Finding(
                pass_name="ranges", rule="no-feasible-design",
                severity=ERROR, where=f"{prefix}{site.name}",
                message=f"no (design, bits) candidate among "
                        f"{list(designs)} x {list(bits_candidates)} can "
                        f"contract K={site.k} inside its accumulator "
                        f"envelope"))
    return findings, checked


def check_model(cfg, *, arch: str = "",
                designs: Sequence[str] = ("bgemm", "ugemm", "tugemm",
                                          "tubgemm"),
                bits_candidates: Sequence[int] = (2, 4, 8),
                grids: Sequence[tuple[int, int]] = ((1, 1), (2, 2), (4, 1)),
                batch: int = 1, seq_len: int = 8,
                ) -> tuple[list[Finding], dict]:
    """Run the full numeric-range pass for one model config.

    Discovery, jaxpr cross-check, and the envelope sweep, all on abstract
    parameters.  Returns ``(findings, stats)`` where stats summarizes the
    coverage (sites, dot_generals, points checked).
    """
    from repro.eval import planner

    params = abstract_params(cfg)
    sites = planner.discover_sites(cfg, params, batch=batch,
                                   seq_len=seq_len)
    dots = collect_dot_generals(cfg, params, batch=batch, seq_len=seq_len)
    findings = cross_check(sites, dots, arch=arch)
    sweep, checked = range_sweep(sites, designs=designs,
                                 bits_candidates=bits_candidates,
                                 grids=grids, arch=arch)
    findings.extend(sweep)
    stats = {"arch": arch, "sites": len(sites), "dot_generals": len(dots),
             "points_checked": checked}
    return findings, stats
