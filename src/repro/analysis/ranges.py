"""Interval arithmetic over per-design accumulator magnitudes.

The paper's exactness claims are *envelope* claims: each design's result is
bit-exact only while its accumulator register can represent the largest
partial value the contraction can produce.  This module computes those
worst-case (and sparsity-informed) magnitudes symbolically, so a (design,
bits, K) point can be proved safe before anything executes:

* ``bgemm`` / ``tugemm`` / ``tubgemm`` accumulate int32 partial sums whose
  functional magnitude is bounded by ``K * Vmax(bits)^2``; tuGEMM's counter
  bank additionally counts up to ``K * L^2`` pulses per output with
  ``L = 2^(bits-1)`` slots (the slot-parallel contraction sums one {-1, 0,
  1} increment per (slot_a, slot_b, k) triple), so its register bound is
  the pulse count, which dominates the functional bound.
* ``ugemm`` keeps its pulse counts in float32 (the BLAS-path trade
  documented in ``gemm_sims.ugemm_stream``): counts are exact integers only
  inside the fp32 exact-integer window, i.e. while ``L * K < 2^24`` with
  ``L = 2^bits`` slots.
* ``ugemm_stochastic`` (the rate-coded family in ``repro.stochastic``)
  accumulates signed AND-pulse counts in an int32 adder tree: up to one
  pulse per (cycle, k) pair, so its register bound is ``K * stream_len``
  against int32 capacity.  The *count* is exact inside that envelope; the
  decoded *estimate* is not — its accuracy model is the separate
  :func:`stochastic_error_bound` (expected + tail relative RMSE vs exact
  uGEMM as a function of stream length), which the planner's accuracy
  guard and ``plan-lint``'s ``stream-guard`` rule consume.

Everything here is closed-form python arithmetic — no JAX — so the runtime
guards in ``repro.backends`` can import it without cost and the property
tests can brute-force-check it against the simulators.

Pallas kernel mirrors (``tugemm_pallas``…) inherit their sibling's
envelope: :func:`design_family` strips the ``_pallas`` suffix, mirroring
``repro.backends.registry.KERNEL_SIBLINGS``; spec spellings like
``"ugemm_stochastic:64"`` strip the stream-length suffix the same way.
"""

from __future__ import annotations

import dataclasses
import math

from repro.analysis.findings import ERROR, Finding
from repro.core.quantization import vmax

INT32_MAX = 2**31 - 1
#: Largest integer window in which every fp32 value is exact — uGEMM's
#: float-held pulse counts are bit-exact only strictly below 2^24.
FLOAT32_EXACT_MAX = 2**24 - 1

_PALLAS_SUFFIX = "_pallas"

#: The rate-coded family whose per-step pulse count is its *stream length*
#: (a plannable knob) rather than a function of the bit-width.
STOCHASTIC_FAMILY = "ugemm_stochastic"

#: Designs with a closed-form accumulator model: the paper's four units
#: plus the rate-coded stochastic family layered on uGEMM.
FAMILIES = ("bgemm", "ugemm", "tugemm", "tubgemm", STOCHASTIC_FAMILY)


@dataclasses.dataclass(frozen=True)
class Interval:
    """A closed interval ``[lo, hi]`` with the arithmetic the bounds need."""

    lo: float
    hi: float

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"empty interval: [{self.lo}, {self.hi}]")

    @classmethod
    def point(cls, v: float) -> "Interval":
        return cls(v, v)

    @classmethod
    def symmetric(cls, mag: float) -> "Interval":
        """``[-mag, +mag]`` — the value set of a signed magnitude bound."""
        return cls(-mag, mag)

    def __add__(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def __mul__(self, other: "Interval") -> "Interval":
        corners = (self.lo * other.lo, self.lo * other.hi,
                   self.hi * other.lo, self.hi * other.hi)
        return Interval(min(corners), max(corners))

    def scale(self, n: float) -> "Interval":
        """n-fold sum of independent copies (n >= 0): ``[n*lo, n*hi]``."""
        if n < 0:
            raise ValueError("scale expects a non-negative repeat count")
        return Interval(self.lo * n, self.hi * n)

    def contains(self, value: float) -> bool:
        return self.lo <= value <= self.hi

    @property
    def abs_max(self) -> float:
        return max(abs(self.lo), abs(self.hi))


def design_family(design: str) -> str:
    """Canonical envelope family of a design name (mirrors inherit).

    Spec spellings carrying a stream length (``"ugemm_stochastic:64"``)
    canonicalise to the bare family name.
    """
    base = design.partition(":")[0]
    if base.endswith(_PALLAS_SUFFIX):
        base = base[:-len(_PALLAS_SUFFIX)]
    return base


def _effective_k(k: int, word_sparsity: float) -> int:
    """Contraction terms that can be non-zero given a word-sparsity bound.

    ``word_sparsity`` is the fraction of exactly-zero quantized words (the
    planner's profiled ``stats.word``); a zero word contributes nothing to
    any accumulator, so at most ``ceil(k * (1 - s))`` terms carry magnitude.
    0.0 (the default) is the worst case.
    """
    if not 0.0 <= word_sparsity <= 1.0:
        raise ValueError(f"word_sparsity must be in [0, 1], "
                         f"got {word_sparsity}")
    return min(k, math.ceil(k * (1.0 - word_sparsity)))


def output_interval(design: str, bits: int, k: int, *,
                    word_sparsity: float = 0.0) -> Interval:
    """Interval containing the design's (M, N) output values.

    For the exact designs the output *is* the int32 accumulator; for uGEMM
    the estimate ``count * V^2/L <= |a||b|``-ish is still bounded by the
    same functional product sum.  Built from first principles with interval
    arithmetic: k-fold sum of the product of two ``[-V, +V]`` code
    intervals.
    """
    family = design_family(design)
    if family not in FAMILIES:
        raise KeyError(f"no accumulator model for design {design!r} "
                       f"(families: {FAMILIES})")
    v = Interval.symmetric(vmax(bits))
    return (v * v).scale(_effective_k(k, word_sparsity))


def counter_interval(design: str, bits: int, k: int, *,
                     word_sparsity: float = 0.0,
                     stream_len: int | None = None) -> Interval:
    """Interval of the *register* each design actually accumulates in.

    This is what capacity is checked against, and it can exceed the
    functional output bound: tuGEMM's counter sums one signed pulse per
    (slot_a, slot_b, k) triple — up to ``L^2`` per step, L = 2^(bits-1) —
    and uGEMM counts up to ``L = 2^bits`` AND-pulses per step before
    rescaling.  bgemm/tubgemm registers hold the functional partial sum
    itself (tubGEMM's slot weights sum back to the operand magnitude).
    The stochastic family counts up to ``stream_len`` signed AND-pulses
    per step (default one full period, ``2^bits``).
    """
    family = design_family(design)
    if family in ("bgemm", "tubgemm"):
        return output_interval(design, bits, k, word_sparsity=word_sparsity)
    if family == "tugemm":
        per_step = Interval.symmetric(2 ** (bits - 1)) \
            * Interval.symmetric(2 ** (bits - 1))
        return per_step.scale(_effective_k(k, word_sparsity))
    if family == "ugemm":
        per_step = Interval.symmetric(2 ** bits)
        return per_step.scale(_effective_k(k, word_sparsity))
    if family == STOCHASTIC_FAMILY:
        per_step = Interval.symmetric(
            2 ** bits if stream_len is None else stream_len)
        return per_step.scale(_effective_k(k, word_sparsity))
    raise KeyError(f"no accumulator model for design {design!r} "
                   f"(families: {FAMILIES})")


def capacity(design: str, bits: int) -> int:
    """Largest accumulator magnitude the design represents exactly."""
    if design_family(design) == "ugemm":
        return FLOAT32_EXACT_MAX
    return INT32_MAX


@dataclasses.dataclass(frozen=True)
class AccumulatorBound:
    """The verdict for one (design, bits, K) point."""

    design: str
    bits: int
    k: int
    interval: Interval        # register interval (capacity domain)
    output: Interval          # functional output interval
    capacity: int
    word_sparsity: float = 0.0
    stream_len: int | None = None

    @property
    def ok(self) -> bool:
        return self.interval.abs_max <= self.capacity

    @property
    def headroom(self) -> float:
        """capacity / |register| — > 1 means safe, with margin."""
        mag = self.interval.abs_max
        return math.inf if mag == 0 else self.capacity / mag

    def describe(self) -> str:
        kind = ("fp32 exact-int window" if design_family(self.design)
                == "ugemm" else "int32 accumulator")
        stream = (f" L={self.stream_len}" if self.stream_len is not None
                  else "")
        return (f"{self.design}@{self.bits}b{stream} K={self.k}: register "
                f"magnitude <= {self.interval.abs_max:.0f} vs {kind} "
                f"capacity {self.capacity} (headroom {self.headroom:.2f}x)")


def accumulator_bound(design: str, bits: int, k: int, *,
                      word_sparsity: float = 0.0,
                      stream_len: int | None = None) -> AccumulatorBound:
    """Bound the accumulator of a (·, K) x (K, ·) contraction.

    Raises ``KeyError`` for designs without an accumulator model — callers
    linting user plans should catch it and emit an ``unknown-design``
    finding instead.  ``stream_len`` scales the stochastic family's
    per-step pulse count; it is ignored for every other family.
    """
    if k < 0:
        raise ValueError(f"contraction length must be >= 0, got k={k}")
    return AccumulatorBound(
        design=design, bits=bits, k=k,
        interval=counter_interval(design, bits, k,
                                  word_sparsity=word_sparsity,
                                  stream_len=stream_len),
        output=output_interval(design, bits, k,
                               word_sparsity=word_sparsity),
        capacity=capacity(design, bits),
        word_sparsity=word_sparsity,
        stream_len=(stream_len
                    if design_family(design) == STOCHASTIC_FAMILY else None))


def max_safe_k(design: str, bits: int,
               stream_len: int | None = None) -> int:
    """Largest K for which ``accumulator_bound(design, bits, K).ok``.

    Closed form: the register magnitude is ``K * u`` for a per-step unit
    ``u`` (``Vmax^2``, ``L^2`` pulses, or ``L`` counts), so the envelope
    edge is ``capacity // u``.  0 means no contraction length is safe at
    this width (e.g. hypothetical ``ugemm`` above 24 bits).
    """
    per_step = counter_interval(design, bits, 1,
                                stream_len=stream_len).abs_max
    if per_step == 0:
        return INT32_MAX
    return int(capacity(design, bits) // per_step)


def check_gemm(design: str, bits: int, k: int, *, where: str,
               word_sparsity: float = 0.0,
               stream_len: int | None = None) -> Finding | None:
    """A ranges-pass finding if the point leaves its envelope, else None."""
    try:
        bound = accumulator_bound(design, bits, k,
                                  word_sparsity=word_sparsity,
                                  stream_len=stream_len)
    except KeyError:
        return Finding(
            pass_name="ranges", rule="unknown-design", severity=ERROR,
            where=where,
            message=f"design {design!r} has no accumulator model "
                    f"(families: {', '.join(FAMILIES)})")
    if bound.ok:
        return None
    return Finding(
        pass_name="ranges", rule="acc-overflow", severity=ERROR,
        where=where,
        message=f"{bound.describe()} — exceeds envelope; largest safe K "
                f"is {max_safe_k(design, bits, stream_len=stream_len)}")


def assert_within_envelope(design: str, bits: int, k: int, *,
                           where: str = "",
                           stream_len: int | None = None) -> None:
    """Runtime guard used by ``GemmBackend.execute`` and the grid path.

    Raises ``ValueError`` with an actionable message when the contraction
    would leave the design's validated accumulator envelope.  Unknown
    designs pass (custom registrations carry their own numerics contract).
    """
    try:
        bound = accumulator_bound(design, bits, k, stream_len=stream_len)
    except KeyError:
        return
    if bound.ok:
        return
    site = f" at {where}" if where else ""
    family = design_family(design)
    fix = (f"split the contraction (e.g. a GridBackend with units_x >= "
           f"{math.ceil(k / max(max_safe_k(design, bits, stream_len=stream_len), 1))}) "
           f"or use an int32-accumulating design"
           if family == "ugemm" else
           "shard the contraction over a GridBackend or lower the "
           "bit-width")
    raise ValueError(
        f"{design}@{bits}b cannot run a K={k} contraction{site}: "
        f"{bound.describe()}; results would silently stop being "
        f"bit-exact (largest safe K is "
        f"{max_safe_k(design, bits, stream_len=stream_len)}) — {fix}")


# ---------------------------------------------------------------------------
# Stochastic accuracy envelope (rate-coded estimate vs exact uGEMM)
# ---------------------------------------------------------------------------

#: Calibrated coefficients of the expected relative-RMSE model
#: ``c1 / stream_len + c2 / 2^bits`` — fit to upper-bound the measured
#: Sobol-paired curves in ``repro.stochastic.error`` (see
#: ``benchmarks/stochastic_bench.py``, which gates measurements against
#: the tail bound on every run).  The ``1/L`` term is the low-discrepancy
#: pairing error; the ``1/2^bits`` term is the SourceGen-rounding floor
#: no stream length can cross.
STOCHASTIC_ERR_C1 = 2.5
STOCHASTIC_ERR_C2 = 4.0
#: Tail multiplier: measured per-site RMSE stays below ``tail = 2x
#: expected`` across seeds/shapes in calibration.
STOCHASTIC_ERR_TAIL = 2.0


@dataclasses.dataclass(frozen=True)
class StochasticErrorBound:
    """Analytic accuracy envelope of one ``(bits, stream_len)`` engine.

    ``expected`` / ``tail`` are *relative RMSE vs exact uGEMM* (the oracle
    the family replaces); squares of these are comparable to the planner's
    per-site relative-MSE guard.
    """

    bits: int
    stream_len: int
    expected: float
    tail: float

    @property
    def expected_rel_mse(self) -> float:
        return self.expected ** 2

    @property
    def tail_rel_mse(self) -> float:
        return self.tail ** 2

    def describe(self) -> str:
        return (f"{STOCHASTIC_FAMILY}@{self.bits}b L={self.stream_len}: "
                f"expected rel-RMSE {self.expected:.4f} "
                f"(tail {self.tail:.4f}) vs exact uGEMM")


def stochastic_error_bound(bits: int, stream_len: int) -> StochasticErrorBound:
    """Closed-form expected/tail error of the rate-coded family.

    This is the *static* half of the stochastic accuracy story: the
    planner pre-filters ``(bits, stream_len)`` candidates whose expected
    error already violates the accuracy guard, and ``plan-lint`` re-derives
    the same bound from a serialized plan (no JAX, no measurement).  The
    *measured* half — seeded per-site RMSE curves — lives in
    ``repro.stochastic.error``.
    """
    if stream_len < 1:
        raise ValueError(f"stream_len must be >= 1, got {stream_len}")
    expected = STOCHASTIC_ERR_C1 / stream_len + STOCHASTIC_ERR_C2 / 2 ** bits
    return StochasticErrorBound(
        bits=bits, stream_len=stream_len, expected=expected,
        tail=STOCHASTIC_ERR_TAIL * expected)
