"""Static lint for ``BackendPlan`` / ``GridPlan`` documents.

A plan is a claim: "these (pattern -> design@bits) assignments are what the
model should execute".  This pass checks the claim without running
anything:

* ``unknown-design`` / ``invalid-bits`` — the assignment names a design
  outside the registry (+ kernel mirrors) or a bit-width the int8 code
  container cannot hold;
* ``shadowed-pattern`` / ``dead-pattern`` — fnmatch resolution semantics
  (exact > most-literal glob > earliest) make the entry unreachable, either
  intrinsically (a duplicate pattern) or against a concrete site inventory
  (the entry matches sites but wins none of them / matches nothing);
* ``unmatched-site`` — a site in the inventory no entry covers (it runs on
  the float path by contract; usually intentional, hence a warning);
* ``guard-relaxed`` — the planner shipped an assignment whose quantization
  error exceeded the accuracy guard (every bit-width failed);
* ``acc-overflow`` — the assignment's recorded contraction geometry leaves
  the design's accumulator envelope (:mod:`repro.analysis.ranges`); for
  grid plans, per-shard entries check their shard-local K and aggregate
  entries check the geometry's padded K split;
* ``invalid-stream`` / ``stream-guard`` — stream-length hygiene for the
  rate-coded ``ugemm_stochastic`` family: a stochastic entry must carry
  ``stream_len >= 1`` (and no count-exact design may carry one), and its
  analytic expected-error bound
  (:func:`repro.analysis.ranges.stochastic_error_bound`) squared must stay
  within the plan's recorded ``max_rel_mse`` accuracy guard — the same
  pre-filter the planner applies, re-derived statically from the document;
* ``packed-width-mismatch`` — when the caller supplies the widths of a
  bit-packed weight store (``packed_bits``, site name -> stored bits, e.g.
  from :func:`repro.core.packing.packed_widths`), every packed site must
  resolve to an entry assigning exactly that width: executing a 4-bit plan
  against an 8-bit store either re-rounds frozen codes or raises at trace
  time (``models/common``'s runtime guard) — the plan and the store were
  built from different planning runs.

Site inventories come from the plan's own evidence by default (entries
record ``k``/``n_out``), or from a model trace when the caller has one.
"""

from __future__ import annotations

import fnmatch
import pathlib
from typing import Mapping, Sequence

from repro.analysis import ranges
from repro.analysis.findings import ERROR, WARNING, Finding
from repro.backends.grid import GridPlan, load_plan
from repro.backends.plan import BackendPlan, SiteAssignment, _specificity
from repro.core import gemm_sims

#: Bit-widths the quantized int8 code container supports (vmax needs >= 2,
#: vmax(8) = 127 is the container ceiling).
VALID_BITS = range(2, 9)


def _known_designs() -> set[str]:
    from repro.backends.registry import KERNEL_SIBLINGS, STOCHASTIC_DESIGN
    return set(gemm_sims.DESIGNS) | set(KERNEL_SIBLINGS) | {STOCHASTIC_DESIGN}


def _stream_findings(entry: SiteAssignment, *, where: str,
                     max_rel_mse: float | None) -> list[Finding]:
    """``invalid-stream`` / ``stream-guard`` rules for one entry."""
    from repro.backends.registry import STOCHASTIC_DESIGN
    out: list[Finding] = []
    is_stochastic = ranges.design_family(entry.design) == STOCHASTIC_DESIGN
    if not is_stochastic:
        if entry.stream_len:
            out.append(Finding(
                pass_name="plan-lint", rule="invalid-stream", severity=ERROR,
                where=where,
                message=f"stream_len={entry.stream_len} on count-exact "
                        f"design {entry.design!r} — stream length is a "
                        f"{STOCHASTIC_DESIGN!r} knob"))
        return out
    if entry.stream_len < 1:
        out.append(Finding(
            pass_name="plan-lint", rule="invalid-stream", severity=ERROR,
            where=where,
            message=f"stochastic entry needs stream_len >= 1, got "
                    f"{entry.stream_len}"))
        return out
    if max_rel_mse is not None and not entry.guard_relaxed \
            and entry.bits in VALID_BITS:
        bound = ranges.stochastic_error_bound(entry.bits, entry.stream_len)
        if bound.expected_rel_mse > float(max_rel_mse):
            out.append(Finding(
                pass_name="plan-lint", rule="stream-guard", severity=ERROR,
                where=where,
                message=f"{bound.describe()} — expected stream error "
                        f"(rel MSE {bound.expected_rel_mse:.4f}) alone "
                        f"violates the plan's accuracy guard "
                        f"max_rel_mse={float(max_rel_mse)}; lengthen the "
                        f"stream or drop the entry"))
    return out


def _entry_findings(entry: SiteAssignment, *, where: str,
                    k_override: int | None = None,
                    max_rel_mse: float | None = None) -> list[Finding]:
    out: list[Finding] = []
    if entry.design not in _known_designs():
        out.append(Finding(
            pass_name="plan-lint", rule="unknown-design", severity=ERROR,
            where=where,
            message=f"design {entry.design!r} is not a registered design "
                    f"or kernel mirror ({sorted(_known_designs())})"))
    if entry.bits not in VALID_BITS:
        out.append(Finding(
            pass_name="plan-lint", rule="invalid-bits", severity=ERROR,
            where=where,
            message=f"bits={entry.bits} outside the int8 code container "
                    f"range [{VALID_BITS.start}, {VALID_BITS.stop - 1}]"))
    if entry.guard_relaxed:
        out.append(Finding(
            pass_name="plan-lint", rule="guard-relaxed", severity=WARNING,
            where=where,
            message=f"assignment shipped with the accuracy guard relaxed "
                    f"(rel_mse={entry.rel_mse:.4f}); quantization error "
                    f"exceeded the planning threshold at every bit-width"))
    out.extend(_stream_findings(entry, where=where, max_rel_mse=max_rel_mse))
    k = entry.k if k_override is None else k_override
    if k and entry.design in _known_designs() \
            and entry.bits in VALID_BITS:
        f = ranges.check_gemm(entry.design, entry.bits, int(k), where=where,
                              stream_len=entry.stream_len or None)
        if f is not None:
            out.append(f)
    return out


def _pattern_findings(plan: BackendPlan, *,
                      site_names: Sequence[str] | None,
                      where_prefix: str) -> list[Finding]:
    out: list[Finding] = []
    # Intrinsic shadowing: resolution is (specificity, earliest), so a
    # later entry with a pattern another entry already states can never
    # win any site the earlier one matches.
    seen: dict[str, int] = {}
    for i, entry in enumerate(plan.sites):
        if entry.pattern in seen:
            out.append(Finding(
                pass_name="plan-lint", rule="shadowed-pattern",
                severity=ERROR,
                where=f"{where_prefix}sites[{i}] {entry.pattern!r}",
                message=f"duplicate of sites[{seen[entry.pattern]}] — "
                        f"resolution always prefers the earlier entry, so "
                        f"this assignment ({entry.design}@{entry.bits}b) "
                        f"is unreachable"))
        else:
            seen[entry.pattern] = i
    if site_names is None:
        return out
    # Inventory-backed reachability: which entry wins each site?
    wins: dict[int, list[str]] = {i: [] for i in range(len(plan.sites))}
    matched: dict[str, bool] = {}
    for name in site_names:
        best, best_key = None, None
        for i, entry in enumerate(plan.sites):
            if not fnmatch.fnmatch(name, entry.pattern):
                continue
            key = (*_specificity(entry.pattern), -i)
            if best_key is None or key > best_key:
                best, best_key = i, key
        matched[name] = best is not None
        if best is not None:
            wins[best].append(name)
    for i, entry in enumerate(plan.sites):
        if entry.pattern in seen and seen[entry.pattern] != i:
            continue  # already reported as a duplicate
        matches = [n for n in site_names
                   if fnmatch.fnmatch(n, entry.pattern)]
        if not matches:
            out.append(Finding(
                pass_name="plan-lint", rule="dead-pattern", severity=ERROR,
                where=f"{where_prefix}sites[{i}] {entry.pattern!r}",
                message="pattern matches no site in the model — stale "
                        "entry or typo"))
        elif not wins[i]:
            losers = ", ".join(matches[:3])
            out.append(Finding(
                pass_name="plan-lint", rule="shadowed-pattern",
                severity=ERROR,
                where=f"{where_prefix}sites[{i}] {entry.pattern!r}",
                message=f"every matching site (e.g. {losers}) resolves to "
                        f"a more specific or earlier entry — this "
                        f"assignment is unreachable"))
    for name in site_names:
        if not matched[name]:
            out.append(Finding(
                pass_name="plan-lint", rule="unmatched-site",
                severity=WARNING, where=f"{where_prefix}{name}",
                message="no plan entry matches this site — it runs on the "
                        "float path"))
    return out


def _packed_findings(plan: BackendPlan, *,
                     packed_bits: Mapping[str, int] | None,
                     where_prefix: str) -> list[Finding]:
    """``packed-width-mismatch``: the store's frozen widths vs the plan's."""
    out: list[Finding] = []
    if not packed_bits:
        return out
    for name in sorted(packed_bits):
        entry = plan.assignment_for(name)
        if entry is None:
            continue  # unmatched sites run float (dequantized) — no conflict
        if int(entry.bits) != int(packed_bits[name]):
            out.append(Finding(
                pass_name="plan-lint", rule="packed-width-mismatch",
                severity=ERROR, where=f"{where_prefix}{name}",
                message=f"plan assigns {entry.design}@{entry.bits}b but the "
                        f"packed store holds {int(packed_bits[name])}-bit "
                        f"codes — repack from the float parameters with "
                        f"backends.pack_weights(cfg, params, plan)"))
    return out


def lint_backend_plan(plan: BackendPlan, *,
                      site_names: Sequence[str] | None = None,
                      where_prefix: str = "",
                      k_override: int | None = None,
                      packed_bits: Mapping[str, int] | None = None
                      ) -> list[Finding]:
    """All findings for one flat :class:`BackendPlan`."""
    out: list[Finding] = []
    max_rel_mse = plan.metadata().get("max_rel_mse")
    for i, entry in enumerate(plan.sites):
        where = (f"{where_prefix}sites[{i}] {entry.pattern!r} "
                 f"-> {entry.design}@{entry.bits}b")
        out.extend(_entry_findings(entry, where=where,
                                   k_override=k_override,
                                   max_rel_mse=max_rel_mse))
    out.extend(_pattern_findings(plan, site_names=site_names,
                                 where_prefix=where_prefix))
    out.extend(_packed_findings(plan, packed_bits=packed_bits,
                                where_prefix=where_prefix))
    return out


def lint_grid_plan(plan: GridPlan, *,
                   site_names: Sequence[str] | None = None,
                   packed_bits: Mapping[str, int] | None = None
                   ) -> list[Finding]:
    """Findings for a :class:`GridPlan`: per-shard plans check shard-local
    contraction lengths (their entries record the slice dims); the
    aggregate plan is checked at the geometry's padded K split, which is
    what SPMD replay via ``GridBackend`` actually contracts per shard."""
    out: list[Finding] = []
    for key, shard_plan in plan.shards:
        out.extend(lint_backend_plan(shard_plan, site_names=None,
                                     where_prefix=f"shard {key}/"))
    agg = plan.aggregate
    max_rel_mse = agg.metadata().get("max_rel_mse")
    for i, entry in enumerate(agg.sites):
        where = (f"aggregate sites[{i}] {entry.pattern!r} "
                 f"-> {entry.design}@{entry.bits}b "
                 f"[grid {plan.units_x}x{plan.units_y}]")
        k_shard = -(-int(entry.k) // plan.units_x) if entry.k else 0
        out.extend(_entry_findings(entry, where=where, k_override=k_shard,
                                   max_rel_mse=max_rel_mse))
    out.extend(_pattern_findings(agg, site_names=site_names,
                                 where_prefix="aggregate "))
    out.extend(_packed_findings(agg, packed_bits=packed_bits,
                                where_prefix="aggregate "))
    return out


def lint_plan(plan, *, site_names: Sequence[str] | None = None,
              packed_bits: Mapping[str, int] | None = None) -> list[Finding]:
    """Dispatch on plan flavour."""
    if isinstance(plan, GridPlan):
        return lint_grid_plan(plan, site_names=site_names,
                              packed_bits=packed_bits)
    if isinstance(plan, BackendPlan):
        return lint_backend_plan(plan, site_names=site_names,
                                 packed_bits=packed_bits)
    raise TypeError(f"expected BackendPlan or GridPlan, got {type(plan)!r}")


def lint_plan_file(path, *, site_names: Sequence[str] | None = None
                   ) -> list[Finding]:
    """Load (schema-sniffing) and lint one plan JSON document."""
    path = pathlib.Path(path)
    try:
        plan = load_plan(path)
    except Exception as e:  # malformed JSON/schema is itself a finding
        return [Finding(pass_name="plan-lint", rule="unloadable-plan",
                        severity=ERROR, where=str(path),
                        message=f"{type(e).__name__}: {e}")]
    prefix = f"{path.name}: "
    return [Finding(f.pass_name, f.rule, f.severity,
                    f"{prefix}{f.where}", f.message)
            for f in lint_plan(plan, site_names=site_names)]
