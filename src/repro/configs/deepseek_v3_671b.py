"""deepseek-v3-671b — MoE (1 shared + 256 routed, top-8) with MLA.

[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
MLA: q_lora 1536, kv_lora 512, rope/nope head dims 64/128, v 128.
Simplifications noted in DESIGN.md: all layers are MoE (the release uses 3
dense warm-up layers) and MTP heads are not modeled.
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=2048,
        vocab_size=129280,
        attention="mla",
        activation="swiglu",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                      d_ff_expert=2048, capacity_factor=1.25),
        fsdp=True,   # 671B params: optimizer state must shard over data too
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        vocab_size=512, remat=False, fsdp=False,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=1,
                      d_ff_expert=64, capacity_factor=2.0))
