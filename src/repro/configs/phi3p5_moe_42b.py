"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE decoder.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]  32L d_model=4096 32H (GQA kv=8)
d_ff(expert)=6400 vocab=32064, 16 experts top-2.
"""

from repro.models.config import ModelConfig, MoEConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        activation="swiglu",
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400,
                      capacity_factor=1.25),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        vocab_size=512, remat=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      capacity_factor=2.0))
