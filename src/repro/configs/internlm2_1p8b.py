"""internlm2-1.8b — dense GQA decoder.

[arXiv:2403.17297; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""

from repro.models.config import ModelConfig

ARCH_ID = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        activation="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, remat=False)
