"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Each module exposes ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "gemma-7b": "repro.configs.gemma_7b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3p8b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
    "llama3-8b": "repro.configs.llama3_8b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3p5_moe_42b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "chameleon-34b": "repro.configs.chameleon_34b",
}

ARCH_IDS = tuple(_MODULES)

# (arch x shape) grid: seq_len, global_batch, and which step each shape lowers.
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, step="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, step="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, step="decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).smoke_config()


def shape_applicable(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k needs sub-quadratic sequence mixing (see DESIGN.md)."""
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def cells(include_skipped: bool = False):
    """All (arch_id, shape_name) dry-run cells."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            if include_skipped or shape_applicable(cfg, s):
                out.append((a, s))
    return out
