"""The paper's own experimental grid: GEMM designs x bit-widths x array sizes.

This is the configuration the benchmark harness sweeps to regenerate
Tables I-IV and Figures 2-3 (the paper has no model architecture of its own).
"""

import dataclasses

ARCH_ID = "paper-gemm"

DESIGNS = ("ugemm", "tugemm", "tubgemm", "bgemm")
BITS = (2, 4, 8)
SIZES = (16, 32)
TPU_SIZES = (64, 128)           # Table IV: EdgeTPU, CloudTPUv3 (4-bit only)
TPU_BITS = 4
CLOCK_MHZ = 400


@dataclasses.dataclass(frozen=True)
class SweepCell:
    design: str
    bits: int
    n: int


def table_grid() -> list[SweepCell]:
    return [SweepCell(d, b, n) for b in BITS for n in SIZES for d in DESIGNS]


def tpu_grid() -> list[SweepCell]:
    return [SweepCell(d, TPU_BITS, n) for n in TPU_SIZES for d in DESIGNS]
