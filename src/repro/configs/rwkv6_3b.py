"""rwkv6-3b ("Finch") — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536, head_dim 64.
O(1)-state decode makes the long_500k shape runnable.
"""

from repro.models.config import ModelConfig, RWKVConfig

ARCH_ID = "rwkv6-3b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="ssm",
        attention="none",
        num_layers=32,
        d_model=2560,
        num_heads=40,           # d_model / head_dim (bookkeeping only)
        num_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64),
        # 40 heads don't divide the 16-way model axis: run pure DP over the
        # whole mesh with FSDP (see DESIGN.md §Arch-applicability)
        dp_over_model=True,
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=512, remat=False,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8))
