"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64.  The shared attention+MLP block (one weight copy)
is applied every 6 Mamba2 layers (6 sites + 2 tail layers).
"""

from repro.models.config import ModelConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                      conv_kernel=4, chunk=64),
        hybrid_attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, hybrid_attn_every=3,
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, n_groups=1,
                      conv_kernel=4, chunk=8),
        remat=False)
