"""phi3-mini-3.8b — dense RoPE/SwiGLU/GQA decoder.

[arXiv:2404.14219; unverified]  32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064.
"""

from repro.models.config import ModelConfig

ARCH_ID = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        activation="swiglu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=512, remat=False)
