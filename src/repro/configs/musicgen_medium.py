"""musicgen-medium — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.
Backbone only per the assignment: the EnCodec frontend is a stub —
``input_specs()`` supplies precomputed frame embeddings (B, S, D); the
codebook-interleaving pattern is outside scope.
"""

from repro.models.config import ModelConfig

ARCH_ID = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        activation="gelu",
        frontend_stub=True,
        # 24 heads don't divide the 16-way model axis: pure DP + FSDP
        dp_over_model=True,
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, remat=False)
