"""chameleon-34b — early-fusion VLM decoder over mixed text/VQ-image tokens.

[arXiv:2405.09818; unverified]  48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536.  Backbone only: the VQ image tokenizer is a stub —
``input_specs()`` supplies precomputed patch embeddings.
"""

from repro.models.config import ModelConfig

ARCH_ID = "chameleon-34b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        activation="swiglu",
        frontend_stub=True,
        fsdp=True,
        fsdp_inference=False,   # 68 GB bf16 / 16-way TP fits HBM replicated over data
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=192,
        vocab_size=512, remat=False, fsdp=False)
