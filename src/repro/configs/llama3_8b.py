"""llama3-8b — dense GQA decoder with 128k vocab.

[arXiv:2407.21783; unverified]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  Closest assigned arch to the paper's own LLaMA2 sparsity study.
"""

from repro.models.config import ModelConfig

ARCH_ID = "llama3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        activation="swiglu",
        rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=192,
        vocab_size=512, remat=False)
