"""gemma-7b — dense GeGLU decoder, head_dim 256, huge 256k vocab.

[arXiv:2403.08295; hf]  28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.
Tied embeddings with sqrt(d_model) input scaling (Gemma convention).
"""

from repro.models.config import ModelConfig

ARCH_ID = "gemma-7b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256_000,
        activation="geglu",
        tie_embeddings=True,
        scale_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=512, remat=False)
