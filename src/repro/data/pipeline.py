"""Data pipeline: synthetic + file-backed token streams, host-sharded, with
background prefetch.

Every source yields dicts of numpy arrays ``{"tokens": (B, S), "targets":
(B, S)}`` (or ``{"embeds": (B, S, D), ...}`` for frontend-stub archs).  The
loader shards deterministically by (host_index, host_count) so multi-host
launches read disjoint data, and a daemon thread keeps ``prefetch`` batches
ahead of the training loop.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "TokenFile", "Prefetcher", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8                # per-host batch
    seq_len: int = 128
    vocab_size: int = 1024
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    embed_dim: int | None = None       # set for frontend-stub archs
    path: str | None = None            # token file (np.int32 flat) if given


class SyntheticLM:
    """Deterministic synthetic LM stream with learnable structure.

    Tokens follow a noisy order-1 Markov chain (so loss can actually go
    down during example training runs, unlike pure uniform noise).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse-ish transition structure shared across hosts
        self._shift = base.integers(1, v, size=16)
        self._step = 0

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + cfg.host_index) & 0x7FFFFFFF)
        v = cfg.vocab_size
        while True:
            b, s = cfg.batch_size, cfg.seq_len
            first = rng.integers(0, v, size=(b, 1))
            noise = rng.random((b, s - 1))
            shift = self._shift[rng.integers(0, len(self._shift), size=(b, s - 1))]
            toks = np.empty((b, s), np.int32)
            toks[:, :1] = first
            for t in range(1, s):
                det = (toks[:, t - 1] + shift[:, t - 1]) % v
                rand = rng.integers(0, v, size=b)
                toks[:, t] = np.where(noise[:, t - 1] < 0.8, det, rand)
            batch = {"tokens": toks[:, :-1].copy(), "targets": toks[:, 1:].copy()}
            if cfg.embed_dim is not None:
                # frontend stub: precomputed frame/patch embeddings
                batch["embeds"] = rng.standard_normal(
                    (b, s - 1, cfg.embed_dim)).astype(np.float32)
            self._step += 1
            yield batch


class TokenFile:
    """Flat int32 token file, chunked into sequences, host-sharded."""

    def __init__(self, cfg: DataConfig):
        if cfg.path is None:
            raise ValueError("TokenFile needs cfg.path")
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        stride = cfg.seq_len + 1
        n_seq = len(self.tokens) // stride
        order = np.random.default_rng(cfg.seed).permutation(n_seq)
        order = order[cfg.host_index::cfg.host_count]
        i = 0
        while True:
            idxs = []
            while len(idxs) < cfg.batch_size:
                idxs.append(order[i % len(order)])
                i += 1
            seqs = np.stack([self.tokens[j * stride:(j + 1) * stride] for j in idxs])
            yield {"tokens": seqs[:, :-1].astype(np.int32),
                   "targets": seqs[:, 1:].astype(np.int32)}


class Prefetcher:
    """Daemon-thread prefetch queue in front of any batch iterator."""

    def __init__(self, it: Iterator[dict], prefetch: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._err: list[BaseException] = []

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # surfaced on next()
                self._err.append(e)
                self._q.put(None)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if item is None:
            raise self._err[0] if self._err else StopIteration
        return item


def make_pipeline(cfg: DataConfig, prefetch: int = 2) -> Iterator[dict]:
    src = TokenFile(cfg) if cfg.path else SyntheticLM(cfg)
    return Prefetcher(iter(src), prefetch=prefetch)
