"""Data substrate: synthetic/file token pipelines with host sharding."""

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM, TokenFile, make_pipeline

__all__ = ["DataConfig", "Prefetcher", "SyntheticLM", "TokenFile", "make_pipeline"]
